//! `ntangent` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   bench <fig1..fig10|mem|all>   regenerate the paper's figures (CSV + summary)
//!   train                         train a Burgers-profile PINN, save a checkpoint
//!   eval                          evaluate a checkpoint's derivative stack at points
//!   serve                         run the batching derivative-evaluation service
//!   trace                         run a traced workload and print the span tree
//!   info                          tables, op counts and environment info

#[cfg(feature = "reference-oracle")]
use ntangent::bench::kernels;
use ntangent::bench::{
    grid, memory, obs as bench_obs, operators, parallel, passes, profiles, serve, train_par,
    training,
};
use ntangent::coordinator::{BatcherConfig, NativeBackend, OperatorServer, PjrtBackend, Service};
use ntangent::nn::Checkpoint;
use ntangent::ntp::{hardy_ramanujan, partition_count, ActivationKind, NtpEngine, ParallelPolicy};
use ntangent::ntp::stde::exact_direction_count;
use ntangent::pde::{resolve_operator, PdeProblem};
use ntangent::pinn::{
    BurgersLossSpec, DerivEngine, EstimatorMode, MultiPinnSpec, ResilienceConfig, RunHealth,
    StdeConfig, TrainConfig,
};
use ntangent::runtime::{ArtifactManifest, Runtime};
use ntangent::tensor::Tensor;
use ntangent::util::cli::{usage, Args, OptSpec};
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, rest)) => (c.as_str(), rest.to_vec()),
        None => {
            eprintln!("{}", top_usage());
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd {
        "bench" => cmd_bench(&rest),
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "validate" => cmd_validate(&rest),
        "serve" => cmd_serve(&rest),
        "trace" => cmd_trace(&rest),
        "info" => cmd_info(&rest),
        "help" | "--help" | "-h" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{}", top_usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn top_usage() -> String {
    "ntangent — n-TangentProp reproduction (quasilinear higher-order derivatives)\n\
     \nUSAGE: ntangent <COMMAND> [OPTIONS]\n\
     \nCOMMANDS:\n\
     \x20 bench <target>   fig1..fig10|mem|par|kernels|train-par|profiles|operators|serve|obs|all\n\
     \x20 train            train a PINN (Burgers profile, or --pde heat2d|poisson2d|...)\n\
     \x20 eval             evaluate a checkpoint at points (--operator for PDE operators)\n\
     \x20 validate         check a Burgers checkpoint against the analytic profile\n\
     \x20 serve            run the derivative-evaluation service (TCP JSON lines)\n\
     \x20 trace            run a traced workload (forward | jet | train), print the span tree\n\
     \x20 info             show tables / op-count / environment info\n\
     \nRun `ntangent <COMMAND> --help` for options."
        .to_string()
}

// ------------------------------------------------------------------ bench

fn bench_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "out-dir", help: "output directory for CSVs", takes_value: true, default: Some("results") },
        OptSpec { name: "trials", help: "timed trials per cell", takes_value: true, default: None },
        OptSpec { name: "n-max", help: "max derivative order", takes_value: true, default: None },
        OptSpec { name: "cap", help: "seconds before projecting autodiff", takes_value: true, default: None },
        OptSpec { name: "widths", help: "comma list (fig4/fig5)", takes_value: true, default: None },
        OptSpec { name: "depths", help: "comma list (fig4/fig5)", takes_value: true, default: None },
        OptSpec { name: "batches", help: "comma list (fig4/fig5)", takes_value: true, default: None },
        OptSpec { name: "activations", help: "comma list of activations (fig4/fig5): tanh,sin,softplus,gelu", takes_value: true, default: None },
        OptSpec { name: "activation", help: "hidden activation (training figs)", takes_value: true, default: None },
        OptSpec { name: "adam-epochs", help: "training figs", takes_value: true, default: None },
        OptSpec { name: "lbfgs-epochs", help: "training figs", takes_value: true, default: None },
        OptSpec { name: "width", help: "network width (training figs)", takes_value: true, default: None },
        OptSpec { name: "depth", help: "hidden layers (training figs)", takes_value: true, default: None },
        OptSpec { name: "seed", help: "rng seed", takes_value: true, default: None },
        OptSpec { name: "profile", help: "Burgers profile k (fig6)", takes_value: true, default: None },
        OptSpec { name: "no-autodiff", help: "skip the autodiff leg (fig6)", takes_value: false, default: None },
        OptSpec { name: "threads", help: "comma list of worker counts (par, train-par, profiles)", takes_value: true, default: None },
        OptSpec { name: "n", help: "derivative order (par)", takes_value: true, default: None },
        OptSpec { name: "chunk", help: "collocation rows per shard (train-par)", takes_value: true, default: None },
        OptSpec { name: "points", help: "residual collocation points (train-par)", takes_value: true, default: None },
        OptSpec { name: "smoke", help: "CI-sized bench shape (kernels, operators, serve, obs)", takes_value: false, default: None },
        OptSpec { name: "batch", help: "batch size (kernels, obs)", takes_value: true, default: None },
        OptSpec { name: "orders", help: "comma list of derivative orders (kernels, obs)", takes_value: true, default: None },
        OptSpec { name: "sample", help: "kernel-phase sampling stride (obs)", takes_value: true, default: None },
        OptSpec { name: "json", help: "also write a BENCH_*.json to this path (kernels, operators, serve, obs)", takes_value: true, default: None },
        OptSpec { name: "requests", help: "mixed-leg request count (serve)", takes_value: true, default: None },
        OptSpec { name: "connections", help: "concurrent pipelined connections (serve)", takes_value: true, default: None },
        OptSpec { name: "window", help: "in-flight requests per connection (serve)", takes_value: true, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ]
}

fn cmd_bench(raw: &[String]) -> Result<(), String> {
    let specs = bench_specs();
    let args = Args::parse(raw, &specs)?;
    if args.flag("help") {
        println!("{}", usage("bench <target>", "Regenerate the paper's figures", &specs));
        return Ok(());
    }
    let target = args
        .positional()
        .first()
        .ok_or("bench needs a target (fig1..fig10, mem, par, kernels, train-par, profiles, operators, serve, obs, all)")?
        .clone();
    let out_dir = PathBuf::from(args.get("out-dir").unwrap());
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;

    let targets: Vec<String> = if target == "all" {
        [
            "fig1", "fig4", "fig6", "fig8", "fig9", "fig7", "fig10", "mem", "par", "kernels",
            "train-par", "operators", "serve", "obs",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    } else {
        vec![target]
    };

    for t in targets {
        run_bench_target(&t, &args, &out_dir)?;
    }
    Ok(())
}

/// Parse a `--threads` value: `serial` | `auto` | a thread count.
fn parse_policy(s: &str) -> Result<ParallelPolicy, String> {
    match s {
        "serial" => Ok(ParallelPolicy::Serial),
        "auto" => Ok(ParallelPolicy::Auto),
        other => match other.parse::<usize>() {
            Ok(0) | Ok(1) => Ok(ParallelPolicy::Serial),
            Ok(t) => Ok(ParallelPolicy::Fixed(t)),
            Err(_) => Err(format!("bad --threads '{other}' (serial | auto | N)")),
        },
    }
}

/// Parse one activation name, with the registry listed in the error.
fn parse_activation(name: &str) -> Result<ActivationKind, String> {
    ActivationKind::from_name(name).ok_or_else(|| {
        format!(
            "unknown activation '{name}' (registered: {})",
            ActivationKind::ALL
                .iter()
                .map(|k| k.name())
                .collect::<Vec<_>>()
                .join(", ")
        )
    })
}

/// Parse a comma list of activation names.
fn parse_activation_list(list: &str) -> Result<Vec<ActivationKind>, String> {
    list.split(',').map(|p| parse_activation(p.trim())).collect()
}

fn train_cfg_from(args: &Args, default_epochs: (usize, usize)) -> Result<TrainConfig, String> {
    let mut cfg = TrainConfig {
        adam_epochs: default_epochs.0,
        lbfgs_epochs: default_epochs.1,
        ..TrainConfig::default()
    };
    if let Some(v) = args.get("activation") {
        cfg.activation = parse_activation(v)?;
    }
    if let Some(v) = args.get_usize("adam-epochs")? {
        cfg.adam_epochs = v;
    }
    if let Some(v) = args.get_usize("lbfgs-epochs")? {
        cfg.lbfgs_epochs = v;
    }
    if let Some(v) = args.get_usize("width")? {
        cfg.width = v;
    }
    if let Some(v) = args.get_usize("depth")? {
        cfg.depth = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    Ok(cfg)
}

fn run_bench_target(target: &str, args: &Args, out_dir: &Path) -> Result<(), String> {
    match target {
        "fig1" | "fig2" | "fig3" => {
            let mut cfg = passes::PassesConfig::default();
            if let Some(v) = args.get_usize("trials")? {
                cfg.trials = v;
            }
            if let Some(v) = args.get_usize("n-max")? {
                cfg.n_max = v;
            }
            if let Some(v) = args.get_f64("cap")? {
                cfg.cap_seconds = v;
            }
            eprintln!(
                "[bench] figs 1-3: pass times, 3x24 net, batch 256, n <= {}",
                cfg.n_max
            );
            let ms = passes::run(&cfg);
            passes::save(&ms, out_dir).map_err(|e| e.to_string())?;
            println!("{}", passes::summarize(&ms));
        }
        "fig4" | "fig5" => {
            let mut cfg = grid::GridConfig::default();
            if let Some(v) = args.get_usize_list("widths")? {
                cfg.widths = v;
            }
            if let Some(v) = args.get_usize_list("depths")? {
                cfg.depths = v;
            }
            if let Some(v) = args.get_usize_list("batches")? {
                cfg.batches = v;
            }
            if let Some(v) = args.get("activations") {
                cfg.activations = parse_activation_list(v)?;
            }
            if let Some(v) = args.get_usize("trials")? {
                cfg.trials = v;
            }
            if let Some(v) = args.get_usize("n-max")? {
                cfg.n_max = v;
            }
            if let Some(v) = args.get_f64("cap")? {
                cfg.cap_seconds = v;
            }
            let ms = grid::run(&cfg, |msg| eprintln!("[bench] {msg}"));
            grid::save(&ms, out_dir).map_err(|e| e.to_string())?;
            println!(
                "wrote fig4_forward_ratio.csv / fig5_total_ratio.csv ({} measurements)",
                ms.len()
            );
        }
        "fig6" => {
            let k = args.get_usize("profile")?.unwrap_or(1);
            let cfg = training::TrainingBenchConfig {
                profile_k: k,
                train: train_cfg_from(args, (300, 300))?,
                spec_overrides: None,
                run_autodiff: !args.flag("no-autodiff"),
            };
            eprintln!("[bench] fig6: profile-{k} training, both engines");
            let result = training::run(&cfg);
            let fname = if k == 1 {
                "fig6_training.csv".to_string()
            } else {
                format!("fig6_training_k{k}.csv")
            };
            training::save(&result, &out_dir.join(fname)).map_err(|e| e.to_string())?;
            println!("{}", training::summarize(&result));
        }
        "fig7" | "fig8" | "fig9" | "fig10" => {
            let k = match target {
                "fig8" => 1,
                "fig9" => 2,
                "fig7" => 3,
                _ => 4,
            };
            let mut cfg = profiles::ProfilesConfig::for_profile(k);
            cfg.train = train_cfg_from(args, (300, 300))?;
            eprintln!(
                "[bench] {target}: Burgers profile k={k} ({} derivatives)",
                2 * k + 1
            );
            let run = profiles::run(&cfg);
            profiles::save(&run, k, out_dir).map_err(|e| e.to_string())?;
            println!("{}", profiles::summarize(&run));
        }
        "mem" => {
            let mut cfg = memory::MemoryConfig::default();
            if let Some(v) = args.get_usize("n-max")? {
                cfg.n_max = v;
            }
            let cells = memory::run(&cfg);
            memory::save(&cells, &out_dir.join("mem_scaling.csv")).map_err(|e| e.to_string())?;
            println!("{}", memory::summarize(&cells));
        }
        "par" | "parallel" => {
            let mut cfg = parallel::ParallelBenchConfig::default();
            if let Some(v) = args.get_usize_list("batches")? {
                cfg.batches = v;
            }
            if let Some(v) = args.get_usize_list("threads")? {
                cfg.threads = v;
            }
            if let Some(v) = args.get_usize("n")? {
                cfg.n = v;
            }
            if let Some(v) = args.get_usize("trials")? {
                cfg.trials = v;
            }
            if let Some(v) = args.get("activation") {
                cfg.activation = parse_activation(v)?;
            }
            eprintln!(
                "[bench] par: serial vs parallel forward, n={}, batches {:?}, threads {:?}",
                cfg.n, cfg.batches, cfg.threads
            );
            let cells = parallel::run(&cfg, |msg| eprintln!("[bench] {msg}"));
            parallel::save(&cells, out_dir).map_err(|e| e.to_string())?;
            println!("{}", parallel::summarize(&cells));
        }
        #[cfg(not(feature = "reference-oracle"))]
        "kernels" => {
            eprintln!(
                "[bench] kernels needs the pre-fusion oracle; rebuild with \
                 `--features reference-oracle`"
            );
        }
        #[cfg(feature = "reference-oracle")]
        "kernels" => {
            let mut cfg = if args.flag("smoke") {
                kernels::KernelBenchConfig::smoke()
            } else {
                kernels::KernelBenchConfig::default()
            };
            if let Some(v) = args.get_usize("batch")? {
                cfg.batch = v.max(1);
            }
            if let Some(v) = args.get_usize_list("orders")? {
                cfg.orders = v;
            }
            if let Some(v) = args.get_usize("width")? {
                cfg.width = v;
            }
            if let Some(v) = args.get_usize("depth")? {
                cfg.depth = v;
            }
            if let Some(v) = args.get("activation") {
                cfg.activation = parse_activation(v)?;
            }
            if let Some(v) = args.get_usize("trials")? {
                cfg.trials = v;
            }
            eprintln!(
                "[bench] kernels: fused vs reference forward, {}x{} {} net, B={}, n {:?}, \
                 parallel leg Fixed({})",
                cfg.depth,
                cfg.width,
                cfg.activation.name(),
                cfg.batch,
                cfg.orders,
                cfg.par_threads
            );
            let cells = kernels::run(&cfg, |msg| eprintln!("[bench] {msg}"));
            kernels::save(&cells, out_dir).map_err(|e| e.to_string())?;
            if let Some(p) = args.get("json") {
                kernels::save_json(&cfg, &cells, Path::new(p)).map_err(|e| e.to_string())?;
                eprintln!("[bench] wrote {p}");
            }
            println!("{}", kernels::summarize(&cells));
        }
        "operators" | "ops" => {
            let mut cfg = if args.flag("smoke") {
                operators::OperatorBenchConfig::smoke()
            } else {
                operators::OperatorBenchConfig::default()
            };
            if let Some(v) = args.get_usize("batch")? {
                cfg.batch = v.max(1);
            }
            if let Some(v) = args.get_usize("width")? {
                cfg.width = v;
            }
            if let Some(v) = args.get_usize("depth")? {
                cfg.depth = v;
            }
            if let Some(v) = args.get("activation") {
                cfg.activation = parse_activation(v)?;
            }
            if let Some(v) = args.get_usize("trials")? {
                cfg.trials = v;
            }
            eprintln!(
                "[bench] operators: directional n-TP vs nested-tape autodiff, {}x{} {} net, B={}",
                cfg.depth,
                cfg.width,
                cfg.activation.name(),
                cfg.batch
            );
            let cells = operators::run(&cfg, |msg| eprintln!("[bench] {msg}"));
            let hd = operators::run_highdim(&cfg, |msg| eprintln!("[bench] {msg}"));
            operators::save(&cells, out_dir).map_err(|e| e.to_string())?;
            operators::save_highdim(&hd, out_dir).map_err(|e| e.to_string())?;
            if let Some(p) = args.get("json") {
                operators::save_json(&cfg, &cells, &hd, Path::new(p))
                    .map_err(|e| e.to_string())?;
                eprintln!("[bench] wrote {p}");
            }
            println!("{}", operators::summarize(&cells));
            println!("{}", operators::summarize_highdim(&hd));
        }
        "serve" => {
            let mut cfg = if args.flag("smoke") {
                serve::ServeBenchConfig::smoke()
            } else {
                serve::ServeBenchConfig::default()
            };
            if let Some(v) = args.get_usize("requests")? {
                cfg.requests = v.max(1);
            }
            if let Some(v) = args.get_usize("connections")? {
                cfg.connections = v.max(1);
            }
            if let Some(v) = args.get_usize("window")? {
                cfg.window = v.max(1);
            }
            if let Some(v) = args.get_usize("width")? {
                cfg.width = v;
            }
            if let Some(v) = args.get_usize("depth")? {
                cfg.depth = v;
            }
            if let Some(v) = args.get_usize("seed")? {
                cfg.seed = v as u64;
            }
            eprintln!(
                "[bench] serve: {} mixed + {} cached-operator pipelined requests \
                 ({} connections, window {}), {} uncached one-shot baseline",
                cfg.requests,
                cfg.operator_requests,
                cfg.connections,
                cfg.window,
                cfg.baseline_requests
            );
            let cells = serve::run(&cfg, |msg| eprintln!("[bench] {msg}"));
            serve::save(&cells, out_dir).map_err(|e| e.to_string())?;
            if let Some(p) = args.get("json") {
                serve::save_json(&cfg, &cells, Path::new(p)).map_err(|e| e.to_string())?;
                eprintln!("[bench] wrote {p}");
            }
            println!("{}", serve::summarize(&cells));
        }
        "obs" => {
            let mut cfg = if args.flag("smoke") {
                bench_obs::ObsBenchConfig::smoke()
            } else {
                bench_obs::ObsBenchConfig::default()
            };
            if let Some(v) = args.get_usize("batch")? {
                cfg.batch = v.max(1);
            }
            if let Some(v) = args.get_usize_list("orders")? {
                cfg.orders = v;
            }
            if let Some(v) = args.get_usize("width")? {
                cfg.width = v;
            }
            if let Some(v) = args.get_usize("depth")? {
                cfg.depth = v;
            }
            if let Some(v) = args.get("activation") {
                cfg.activation = parse_activation(v)?;
            }
            if let Some(v) = args.get_usize("trials")? {
                cfg.trials = v;
            }
            if let Some(v) = args.get_usize("sample")? {
                cfg.kernel_sample = v.max(1) as u32;
            }
            eprintln!(
                "[bench] obs: traced vs untraced fused forward, {}x{} {} net, B={}, n {:?}, \
                 sampling every {} tiles",
                cfg.depth,
                cfg.width,
                cfg.activation.name(),
                cfg.batch,
                cfg.orders,
                cfg.kernel_sample
            );
            let cells = bench_obs::run(&cfg, |msg| eprintln!("[bench] {msg}"));
            bench_obs::save(&cells, out_dir).map_err(|e| e.to_string())?;
            if let Some(p) = args.get("json") {
                bench_obs::save_json(&cfg, &cells, Path::new(p)).map_err(|e| e.to_string())?;
                eprintln!("[bench] wrote {p}");
            }
            println!("{}", bench_obs::summarize(&cells));
        }
        "profiles" => {
            let k = args.get_usize("profile")?.unwrap_or(2);
            let threads = args
                .get_usize_list("threads")?
                .unwrap_or_else(|| vec![1, 2, 4]);
            let mut base = profiles::ProfilesConfig::for_profile(k);
            base.train = train_cfg_from(args, (300, 300))?;
            let cfgs: Vec<profiles::ProfilesConfig> = threads
                .iter()
                .map(|&t| {
                    let mut c = base.clone();
                    c.train.policy = if t <= 1 {
                        ParallelPolicy::Serial
                    } else {
                        ParallelPolicy::Fixed(t)
                    };
                    c
                })
                .collect();
            eprintln!(
                "[bench] profiles: k={k} full-training sweep over threads {threads:?}, \
                 one shard pool reused across runs"
            );
            let runs = profiles::run_sweep(&cfgs, |msg| eprintln!("[bench] {msg}"));
            let labels: Vec<String> = threads.iter().map(|t| format!("threads-{t}")).collect();
            profiles::save_sweep(&runs, &labels, out_dir).map_err(|e| e.to_string())?;
            for (r, label) in runs.iter().zip(&labels) {
                println!("[{label}] {}", profiles::summarize(r));
            }
        }
        "train-par" | "train_par" => {
            let mut cfg = train_par::TrainParBenchConfig::default();
            if let Some(v) = args.get_usize("profile")? {
                cfg.profile_k = v;
            }
            if let Some(v) = args.get_usize("width")? {
                cfg.width = v;
            }
            if let Some(v) = args.get_usize("depth")? {
                cfg.depth = v;
            }
            if let Some(v) = args.get("activation") {
                cfg.activation = parse_activation(v)?;
            }
            if let Some(v) = args.get_usize("points")? {
                cfg.n_res = v;
            }
            if let Some(v) = args.get_usize("chunk")? {
                cfg.chunk = v.max(1);
            }
            if let Some(v) = args.get_usize_list("threads")? {
                cfg.threads = v;
            }
            if let Some(v) = args.get_usize("trials")? {
                cfg.trials = v;
            }
            if let Some(v) = args.get_usize("seed")? {
                cfg.seed = v as u64;
            }
            eprintln!(
                "[bench] train-par: serial vs data-parallel training step, \
                 {} res + {} org pts, chunk {}, threads {:?}",
                cfg.n_res, cfg.n_org, cfg.chunk, cfg.threads
            );
            let cells = train_par::run(&cfg, |msg| eprintln!("[bench] {msg}"));
            train_par::save(&cells, out_dir).map_err(|e| e.to_string())?;
            println!("{}", train_par::summarize(&cells));
        }
        other => return Err(format!("unknown bench target '{other}'")),
    }
    Ok(())
}

// ------------------------------------------------------------------ train

fn cmd_train(raw: &[String]) -> Result<(), String> {
    let specs = vec![
        OptSpec { name: "profile", help: "Burgers profile k (1..4)", takes_value: true, default: Some("1") },
        OptSpec { name: "pde", help: "train a library PDE instead of Burgers: heat2d | poisson2d | wave2d | kdv | biharmonic2d | poisson10d | heat100d | hjb10d", takes_value: true, default: None },
        OptSpec { name: "points", help: "interior collocation points (--pde)", takes_value: true, default: None },
        OptSpec { name: "bc-points", help: "boundary collocation points (--pde)", takes_value: true, default: None },
        OptSpec { name: "estimator", help: "operator residual estimator (--pde): exact | stde", takes_value: true, default: Some("exact") },
        OptSpec { name: "samples", help: "STDE term samples per step and shard", takes_value: true, default: Some("4") },
        OptSpec { name: "antithetic", help: "STDE antithetic pairing (needs an even --samples)", takes_value: false, default: None },
        OptSpec { name: "adam-epochs", help: "Adam epochs", takes_value: true, default: Some("300") },
        OptSpec { name: "lbfgs-epochs", help: "L-BFGS epochs", takes_value: true, default: Some("300") },
        OptSpec { name: "width", help: "network width", takes_value: true, default: Some("24") },
        OptSpec { name: "depth", help: "hidden layers", takes_value: true, default: Some("3") },
        OptSpec { name: "activation", help: "hidden activation: tanh | sin | softplus | gelu", takes_value: true, default: Some("tanh") },
        OptSpec { name: "engine", help: "ntp | autodiff", takes_value: true, default: Some("ntp") },
        OptSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("0") },
        OptSpec { name: "threads", help: "serial = monolithic tape; auto | N = sharded data-parallel", takes_value: true, default: Some("serial") },
        OptSpec { name: "chunk", help: "collocation rows per shard (parallel training)", takes_value: true, default: Some("32") },
        OptSpec { name: "out", help: "checkpoint path", takes_value: true, default: Some("results/checkpoint.json") },
        OptSpec { name: "checkpoint-every", help: "write a crash-safe resume checkpoint to --out every N epochs (0 = only the final artifact)", takes_value: true, default: Some("0") },
        OptSpec { name: "resume", help: "resume a checkpoint written with --checkpoint-every (needs the original profile/config/seed flags)", takes_value: true, default: None },
        OptSpec { name: "max-retries", help: "bounded divergence rollbacks before a clean abort", takes_value: true, default: Some("3") },
        OptSpec { name: "no-guard", help: "disable the per-step numeric-health guards", takes_value: false, default: None },
        OptSpec { name: "telemetry", help: "stream one JSON line per optimizer step to this path (loss, grad norm, λ, retries, timings)", takes_value: true, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &specs)?;
    if args.flag("help") {
        println!("{}", usage("train", "Train a Burgers-profile PINN", &specs));
        return Ok(());
    }
    let k = args.get_usize("profile")?.unwrap();
    let engine = match args.get("engine").unwrap() {
        "ntp" => DerivEngine::Ntp,
        "autodiff" => DerivEngine::Autodiff,
        other => return Err(format!("unknown engine '{other}'")),
    };
    let mut cfg = train_cfg_from(&args, (300, 300))?;
    let threads_arg = args.get("threads").unwrap().to_string();
    cfg.policy = parse_policy(&threads_arg)?;
    if let Some(v) = args.get_usize("chunk")? {
        cfg.chunk = v.max(1);
    }
    let out = PathBuf::from(args.get("out").unwrap());
    let checkpoint_every = args.get_usize("checkpoint-every")?.unwrap();
    let res = ResilienceConfig {
        guard: !args.flag("no-guard"),
        max_retries: args.get_usize("max-retries")?.unwrap() as u64,
        checkpoint_every,
        checkpoint_path: (checkpoint_every > 0).then(|| out.clone()),
        telemetry_path: args.get("telemetry").map(PathBuf::from),
        ..ResilienceConfig::default()
    };
    // `Checkpoint::load` validates shapes and finiteness, so a truncated
    // or corrupted resume file fails here with its taxonomy error instead
    // of poisoning the restarted trajectory.
    let resume_ck = match args.get("resume") {
        Some(p) => Some(Checkpoint::load(Path::new(p)).map_err(|e| format!("--resume: {e:#}"))?),
        None => None,
    };
    let resume = match &resume_ck {
        Some(ck) => {
            let state = ck.resume.as_ref().ok_or(
                "--resume checkpoint carries no mid-run state; \
                 train with --checkpoint-every to produce one",
            )?;
            eprintln!(
                "resuming from {} ({} phase, epoch {})",
                args.get("resume").unwrap(),
                state.phase.name(),
                state.epoch
            );
            Some(state)
        }
        None => None,
    };
    // --- Multi-dimensional PDE training (--pde) -------------------------
    if let Some(pde_name) = args.get("pde") {
        let problem = PdeProblem::from_name(pde_name).ok_or_else(|| {
            format!(
                "unknown PDE '{pde_name}' (library: {})",
                PdeProblem::ALL
                    .iter()
                    .map(|p| p.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
        let mut spec = MultiPinnSpec::for_problem(problem);
        if let Some(v) = args.get_usize("points")? {
            spec.n_interior = v.max(1);
        }
        if let Some(v) = args.get_usize("bc-points")? {
            spec.n_boundary = v;
        }
        let estimator = match args.get("estimator").unwrap() {
            "exact" => EstimatorMode::Exact,
            "stde" => EstimatorMode::Stde {
                seed: cfg.seed,
                samples: args.get_usize("samples")?.unwrap().max(1),
                antithetic: args.flag("antithetic"),
            },
            other => return Err(format!("unknown estimator '{other}' (exact | stde)")),
        };
        if problem.needs_stde() && estimator == EstimatorMode::Exact {
            return Err(format!(
                "{}'s exact direction plan is combinatorially intractable; \
                 pass --estimator stde",
                problem.name()
            ));
        }
        let op = problem.operator();
        // High-dimensional operators have O(dim) terms; keep the banner short.
        let op_desc = if op.terms().len() <= 8 {
            op.describe()
        } else {
            format!("{} terms over {} axes", op.terms().len(), problem.dim())
        };
        let est_desc = match estimator {
            EstimatorMode::Exact => "exact plan".to_string(),
            EstimatorMode::Stde { samples, antithetic, .. } => format!(
                "STDE, K={samples}{}",
                if antithetic { ", antithetic" } else { "" }
            ),
        };
        eprintln!(
            "training PDE {} (L = {op_desc}, order {}) with {engine:?} ({est_desc}), \
             {}x{} {} net, {} + {} points, {:?} gradient accumulation",
            problem.name(),
            op.max_order(),
            cfg.depth,
            cfg.width,
            cfg.activation.name(),
            spec.n_interior,
            spec.n_boundary,
            cfg.policy
        );
        let result =
            ntangent::pinn::train_pde_resilient(spec, &cfg, engine, estimator, &res, resume);
        report_health(&result.health, &res)?;
        println!(
            "done in {:.1}s: loss = {:.3e}, residual RMS = {:.3e}, L2(u) = {:.3e}",
            result.seconds,
            result.final_loss,
            result.residual_rms(256, 1),
            result.solution_l2_error(256, 2),
        );
        let mut ck = Checkpoint::from_mlp(&result.mlp);
        ck.final_loss = Some(result.final_loss);
        ck.save(&out).map_err(|e| e.to_string())?;
        println!("checkpoint -> {}", out.display());
        return Ok(());
    }

    let spec = BurgersLossSpec::for_profile(k);
    eprintln!(
        "training profile k={k} (λ* = {:.6}, {} derivatives) with {engine:?}, {}x{} {} net, \
         {:?} gradient accumulation",
        spec.profile.lambda_smooth(),
        spec.profile.n_derivs(),
        cfg.depth,
        cfg.width,
        cfg.activation.name(),
        cfg.policy
    );
    // Any explicit thread count — including 1 — routes through the sharded
    // data-parallel trainer, whose result is bitwise identical for every
    // count (docs/ARCHITECTURE.md). Only the literal "serial" default keeps
    // the monolithic single-tape path, which sums in a different order.
    let result = if threads_arg == "serial" {
        ntangent::pinn::train_burgers_resilient(spec, &cfg, engine, &res, resume)
    } else {
        ntangent::pinn::train_burgers_parallel_resilient(spec, &cfg, engine, &res, resume)
    };
    report_health(&result.health, &res)?;
    println!(
        "done in {:.1}s: λ = {:.6} (err {:.2e}), loss = {:.3e}, L2(u) = {:.3e}",
        result.seconds,
        result.lambda,
        result.lambda_error(),
        result.final_loss,
        result.solution_l2_error(101),
    );
    let mut ck = Checkpoint::from_mlp(&result.mlp);
    ck.lambda = Some(result.lambda);
    ck.profile_k = Some(k);
    ck.final_loss = Some(result.final_loss);
    ck.save(&out).map_err(|e| e.to_string())?;
    println!("checkpoint -> {}", out.display());
    Ok(())
}

/// Surface a run's [`RunHealth`] on the CLI: warn about degraded
/// durability and survived rollbacks, and turn an interruption or a
/// bounded-retry abort into a non-zero exit (the last-good checkpoint, if
/// one was configured, is already on disk).
fn report_health(health: &RunHealth, res: &ResilienceConfig) -> Result<(), String> {
    if let Some(e) = &health.checkpoint_error {
        eprintln!("warning: checkpoint write failed mid-run: {e}");
    }
    if health.interrupted {
        return Err("training interrupted (injected kill); restart with --resume".into());
    }
    if let Some(err) = health.aborted {
        let hint = match &res.checkpoint_path {
            Some(p) => format!("; last-good checkpoint at {}", p.display()),
            None => String::new(),
        };
        return Err(format!(
            "training aborted after {} rollback(s): {err}{hint}",
            health.retries
        ));
    }
    if health.retries > 0 {
        eprintln!(
            "recovered from {} divergence rollback(s); trajectory completed",
            health.retries
        );
    }
    Ok(())
}

// ------------------------------------------------------------------- eval

fn cmd_eval(raw: &[String]) -> Result<(), String> {
    let specs = vec![
        OptSpec { name: "checkpoint", help: "checkpoint JSON", takes_value: true, default: Some("results/checkpoint.json") },
        OptSpec { name: "points", help: "comma list of x values (';'-separated coordinate rows with --operator)", takes_value: true, default: Some("-1.0,-0.5,0.0,0.5,1.0") },
        OptSpec { name: "n", help: "derivative order", takes_value: true, default: Some("3") },
        OptSpec { name: "operator", help: "evaluate a differential operator: library name (heat2d, ...) or spec like 'd20+d02'", takes_value: true, default: None },
        OptSpec { name: "estimator", help: "operator evaluation (--operator): exact | stde", takes_value: true, default: Some("exact") },
        OptSpec { name: "samples", help: "STDE term samples", takes_value: true, default: Some("4") },
        OptSpec { name: "seed", help: "STDE stream seed", takes_value: true, default: Some("0") },
        OptSpec { name: "antithetic", help: "STDE antithetic pairing (needs an even --samples)", takes_value: false, default: None },
        OptSpec { name: "threads", help: "batch parallelism: serial | auto | N", takes_value: true, default: Some("serial") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &specs)?;
    if args.flag("help") {
        println!("{}", usage("eval", "Evaluate a checkpoint's derivative stack", &specs));
        return Ok(());
    }
    let ck = Checkpoint::load(Path::new(args.get("checkpoint").unwrap()))
        .map_err(|e| e.to_string())?;
    let mlp = ck.to_mlp().map_err(|e| e.to_string())?;
    let n = args.get_usize("n")?.unwrap();
    let policy = parse_policy(args.get("threads").unwrap())?;

    // --- Operator evaluation over multi-dimensional points --------------
    if let Some(op_spec) = args.get("operator") {
        let dim = mlp.input_dim();
        let op = resolve_operator(op_spec, dim)?;
        let rows: Vec<Vec<f64>> = args
            .get("points")
            .unwrap()
            .split(';')
            .map(|grp| {
                grp.split(',')
                    .map(|s| s.trim().parse().map_err(|_| format!("bad coordinate '{s}'")))
                    .collect::<Result<Vec<f64>, String>>()
            })
            .collect::<Result<_, _>>()?;
        for p in &rows {
            if p.len() != dim {
                return Err(format!(
                    "point {p:?} has {} coordinates, the model expects {dim} \
                     (separate points with ';', coordinates with ',')",
                    p.len()
                ));
            }
        }
        let (u, vals) = match args.get("estimator").unwrap() {
            "exact" => {
                // Same evaluator the wire protocol's points_nd requests use.
                let server = OperatorServer::new(mlp, policy);
                server.eval(&rows, op_spec, None)?
            }
            "stde" => {
                let cfg = StdeConfig {
                    seed: args.get_usize("seed")?.unwrap() as u64,
                    samples: args.get_usize("samples")?.unwrap().max(1),
                    antithetic: args.flag("antithetic"),
                };
                let flat: Vec<f64> = rows.iter().flat_map(|p| p.iter().copied()).collect();
                let x = Tensor::from_vec(flat, &[rows.len(), dim]);
                let u = mlp.forward(&x).data().to_vec();
                let est = ntangent::ntp::StdeEngine::with_policy(op.clone(), cfg, policy)
                    .estimate(&mlp, &x, 0);
                eprintln!(
                    "STDE estimate: seed {}, K = {}{}, {} directional passes \
                     (exact plan: {})",
                    cfg.seed,
                    cfg.samples,
                    if cfg.antithetic { " antithetic" } else { "" },
                    est.n_directions,
                    exact_direction_count(dim, op.max_order()),
                );
                (u, est.values.data().to_vec())
            }
            other => return Err(format!("unknown estimator '{other}' (exact | stde)")),
        };
        println!("operator {} (order {})", op.describe(), op.max_order());
        print!("{:>28}", "point");
        print!("{:>16}{:>16}", "u", "L[u]");
        println!();
        for (i, p) in rows.iter().enumerate() {
            let coords: Vec<String> = p.iter().map(|c| format!("{c:.4}")).collect();
            print!("{:>28}", format!("({})", coords.join(", ")));
            print!("{:>16.8}{:>16.8}", u[i], vals[i]);
            println!();
        }
        return Ok(());
    }

    if mlp.input_dim() != 1 {
        return Err(format!(
            "checkpoint has a {}-dimensional input; evaluate it with \
             --operator (library name or spec like 'd20+d02')",
            mlp.input_dim()
        ));
    }
    let points: Vec<f64> = args
        .get("points")
        .unwrap()
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| format!("bad point '{s}'")))
        .collect::<Result<_, _>>()?;
    let engine = NtpEngine::with_policy(n, policy);
    let x = Tensor::from_vec(points.clone(), &[points.len(), 1]);
    let channels = engine.forward(&mlp, &x);
    print!("{:>12}", "x");
    for j in 0..=n {
        print!("{:>16}", format!("u^({j})"));
    }
    println!();
    for (i, &p) in points.iter().enumerate() {
        print!("{p:>12.6}");
        for chan in &channels {
            print!("{:>16.8}", chan.data()[i]);
        }
        println!();
    }
    Ok(())
}

// --------------------------------------------------------------- validate

fn cmd_validate(raw: &[String]) -> Result<(), String> {
    let specs = vec![
        OptSpec { name: "checkpoint", help: "checkpoint JSON (needs profile_k)", takes_value: true, default: Some("results/checkpoint.json") },
        OptSpec { name: "points", help: "grid size", takes_value: true, default: Some("201") },
        OptSpec { name: "x-max", help: "half-width of the validation domain", takes_value: true, default: Some("1.5") },
        OptSpec { name: "threads", help: "batch parallelism: serial | auto | N", takes_value: true, default: Some("auto") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &specs)?;
    if args.flag("help") {
        println!("{}", usage("validate", "Validate a Burgers checkpoint", &specs));
        return Ok(());
    }
    let ck = Checkpoint::load(Path::new(args.get("checkpoint").unwrap()))
        .map_err(|e| e.to_string())?;
    let k = ck
        .profile_k
        .ok_or("checkpoint has no profile_k; was it trained with `ntangent train`?")?;
    let mlp = ck.to_mlp().map_err(|e| e.to_string())?;
    let profile = ntangent::pinn::BurgersProfile::new(k);
    let n_pts = args.get_usize("points")?.unwrap();
    let x_max = args.get_f64("x-max")?.unwrap();
    let policy = parse_policy(args.get("threads").unwrap())?;
    let order_max = k; // the orders the paper plots
    let xs = ntangent::pinn::grid_points(-x_max, x_max, n_pts);
    let channels = ntangent::pinn::eval_channels(&mlp, &xs, order_max, policy);
    println!(
        "profile k={k}: λ* = {:.6}, checkpoint λ = {}",
        profile.lambda_smooth(),
        ck.lambda.map(|l| format!("{l:.6} (err {:.2e})", (l - profile.lambda_smooth()).abs()))
            .unwrap_or_else(|| "—".into())
    );
    println!("{:>8} {:>14} {:>14}", "order", "RMS error", "max |error|");
    for (order, chan) in channels.iter().enumerate() {
        let mut sq = 0.0;
        let mut worst = 0.0f64;
        for (i, &x) in xs.data().iter().enumerate() {
            let truth = profile.derivatives_true(x, order_max)[order];
            let d = chan.data()[i] - truth;
            sq += d * d;
            worst = worst.max(d.abs());
        }
        println!(
            "{order:>8} {:>14.4e} {:>14.4e}",
            (sq / n_pts as f64).sqrt(),
            worst
        );
    }
    Ok(())
}

// ------------------------------------------------------------------ serve

fn cmd_serve(raw: &[String]) -> Result<(), String> {
    let specs = vec![
        OptSpec { name: "checkpoint", help: "checkpoint JSON", takes_value: true, default: Some("results/checkpoint.json") },
        OptSpec { name: "port", help: "TCP port", takes_value: true, default: Some("7474") },
        OptSpec { name: "n", help: "derivative order served", takes_value: true, default: Some("3") },
        OptSpec { name: "backend", help: "native | pjrt", takes_value: true, default: Some("native") },
        OptSpec { name: "artifacts", help: "artifacts dir (pjrt backend)", takes_value: true, default: Some("artifacts") },
        OptSpec { name: "artifact", help: "artifact name (pjrt backend)", takes_value: true, default: Some("ntp_fwd_d3") },
        OptSpec { name: "batch-cap", help: "native backend batch cap", takes_value: true, default: Some("256") },
        OptSpec { name: "workers", help: "batcher workers (activation shards)", takes_value: true, default: Some("1") },
        OptSpec { name: "queue-depth", help: "bounded ingress queue per worker (full = shed with retry_ms)", takes_value: true, default: Some("1024") },
        OptSpec { name: "threads", help: "per-batch parallelism: serial | auto | N", takes_value: true, default: Some("serial") },
        OptSpec { name: "obs", help: "enable tracing spans (also NTANGENT_TRACE=1); inspect via {\"stats\":\"full\"}", takes_value: false, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &specs)?;
    if args.flag("help") {
        println!("{}", usage("serve", "Run the derivative-evaluation service", &specs));
        return Ok(());
    }
    if args.flag("obs") {
        ntangent::obs::set_enabled(true);
    }
    let ck = Checkpoint::load(Path::new(args.get("checkpoint").unwrap()))
        .map_err(|e| e.to_string())?;
    let n = args.get_usize("n")?.unwrap();
    let cap = args.get_usize("batch-cap")?.unwrap();
    let workers = args.get_usize("workers")?.unwrap().max(1);
    let policy = parse_policy(args.get("threads").unwrap())?;
    let backend_kind = args.get("backend").unwrap().to_string();
    let artifacts_dir = PathBuf::from(args.get("artifacts").unwrap());
    let artifact_name = args.get("artifact").unwrap().to_string();

    let theta = Tensor::from_vec(ck.theta.clone(), &[ck.theta.len()]);
    let mlp = ck.to_mlp().map_err(|e| e.to_string())?;
    let op_mlp = mlp.clone();
    let cfg = BatcherConfig {
        queue_depth: args.get_usize("queue-depth")?.unwrap().max(1),
        ..BatcherConfig::default()
    };

    let service = match backend_kind.as_str() {
        "native" => Service::start_pool(
            move |_w| Ok(Box::new(NativeBackend::new_parallel(mlp.clone(), n, cap, policy)) as _),
            workers,
            cfg,
        ),
        "pjrt" => {
            if workers > 1 {
                return Err("pjrt backend serves a single compiled activation; \
                            --workers > 1 needs the native backend"
                    .into());
            }
            if policy != ParallelPolicy::Serial {
                return Err("pjrt backend executes compiled fixed-shape batches; \
                            --threads applies to the native backend"
                    .into());
            }
            Service::start(
                move || {
                    let manifest = ArtifactManifest::load(&artifacts_dir)?;
                    let spec = manifest.get(&artifact_name)?.clone();
                    let rt = Runtime::cpu()?;
                    let exe = rt.load_hlo_text(&manifest.path_of(&spec))?;
                    let batch = spec.batch.unwrap_or(256);
                    let nd = spec.n_derivs.unwrap_or(n);
                    Ok(Box::new(PjrtBackend::new(exe, theta, batch, nd)) as _)
                },
                cfg,
            )
        }
        other => return Err(format!("unknown backend '{other}'")),
    };
    // The operator front serves multivariate `points_nd` requests
    // against the same checkpoint (any input dim), sharing the compile
    // cache and the service's metrics.
    let operator_server = Arc::new(
        OperatorServer::new(op_mlp, policy).with_metrics(service.handle().metrics_handle()),
    );

    let port = args.get_usize("port")?.unwrap();
    let listener =
        std::net::TcpListener::bind(("127.0.0.1", port as u16)).map_err(|e| e.to_string())?;
    eprintln!(
        "serving {backend_kind} backend on 127.0.0.1:{port} \
         ({workers} worker(s), {policy:?} batch parallelism, \
         queue depth {} per worker; framed or line-delimited JSON, pipelined; \
         {{\"points\":[..]}}, \
         {{\"points_nd\":[[..],..],\"operator\":\"d20+d02\"}}, \
         {{\"cmd\":\"stats\"}} or {{\"stats\":\"full\"}})",
        cfg.queue_depth
    );
    ntangent::coordinator::service::serve_tcp_with(
        listener,
        service.handle(),
        Some(operator_server),
    )
    .map_err(|e| e.to_string())
}

// ------------------------------------------------------------------ trace

/// `ntangent trace <forward|jet|train>`: run a small representative
/// workload with tracing enabled, then print the hierarchical span tree
/// and the sampled kernel-phase breakdown (`--json` dumps the full
/// registry + span snapshot instead).
fn cmd_trace(raw: &[String]) -> Result<(), String> {
    let specs = vec![
        OptSpec { name: "n", help: "derivative order", takes_value: true, default: Some("4") },
        OptSpec { name: "batch", help: "batch size of the traced forwards", takes_value: true, default: Some("256") },
        OptSpec { name: "width", help: "network width", takes_value: true, default: Some("24") },
        OptSpec { name: "depth", help: "hidden layers", takes_value: true, default: Some("3") },
        OptSpec { name: "repeats", help: "workload repetitions (forward, jet)", takes_value: true, default: Some("8") },
        OptSpec { name: "adam-epochs", help: "Adam epochs (train)", takes_value: true, default: Some("40") },
        OptSpec { name: "lbfgs-epochs", help: "L-BFGS epochs (train)", takes_value: true, default: Some("20") },
        OptSpec { name: "sample", help: "kernel-phase sampling stride", takes_value: true, default: Some("16") },
        OptSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("0") },
        OptSpec { name: "json", help: "print the JSON snapshot (registry + spans) instead of the tree", takes_value: false, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &specs)?;
    if args.flag("help") {
        println!("{}", usage("trace <target>", "Trace a workload and print the span tree", &specs));
        return Ok(());
    }
    let target = args
        .positional()
        .first()
        .ok_or("trace needs a target (forward | jet | train)")?
        .clone();
    ntangent::obs::ObsConfig {
        enabled: true,
        kernel_sample: args.get_usize("sample")?.unwrap().max(1) as u32,
    }
    .apply();
    ntangent::obs::reset_spans();

    let n = args.get_usize("n")?.unwrap().max(1);
    let batch = args.get_usize("batch")?.unwrap().max(1);
    let width = args.get_usize("width")?.unwrap().max(1);
    let depth = args.get_usize("depth")?.unwrap().max(1);
    let repeats = args.get_usize("repeats")?.unwrap().max(1);
    let seed = args.get_usize("seed")?.unwrap() as u64;
    let mut rng = ntangent::util::prng::Prng::seeded(seed);
    match target.as_str() {
        "forward" => {
            let mlp = ntangent::nn::Mlp::uniform(1, width, depth, 1, &mut rng);
            let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
            let engine = NtpEngine::new(n);
            eprintln!("[trace] {repeats} fused forward_n passes, B={batch}, n={n}");
            for _ in 0..repeats {
                std::hint::black_box(engine.forward_n(&mlp, &x, n));
            }
        }
        "jet" => {
            let dim = 2;
            let mlp = ntangent::nn::Mlp::uniform(dim, width, depth, 1, &mut rng);
            let x = Tensor::rand_uniform(&[batch, dim], -1.0, 1.0, &mut rng);
            let engine = ntangent::ntp::multi::MultiJetEngine::new(dim, n);
            eprintln!("[trace] {repeats} directional jet sets, B={batch}, dim={dim}, n={n}");
            for _ in 0..repeats {
                std::hint::black_box(engine.jet(&mlp, &x).value().data()[0]);
            }
        }
        "train" => {
            let cfg = TrainConfig {
                width,
                depth,
                seed,
                adam_epochs: args.get_usize("adam-epochs")?.unwrap(),
                lbfgs_epochs: args.get_usize("lbfgs-epochs")?.unwrap(),
                ..TrainConfig::default()
            };
            let spec = BurgersLossSpec::for_profile(1);
            eprintln!(
                "[trace] profile-1 training, {} + {} epochs",
                cfg.adam_epochs, cfg.lbfgs_epochs
            );
            let result = ntangent::pinn::train_burgers_resilient(
                spec,
                &cfg,
                DerivEngine::Ntp,
                &ResilienceConfig::default(),
                None,
            );
            eprintln!("[trace] final loss {:.3e}", result.final_loss);
        }
        other => return Err(format!("unknown trace target '{other}' (forward | jet | train)")),
    }

    if args.flag("json") {
        println!("{}", ntangent::obs::export::json_snapshot().dump());
        return Ok(());
    }
    print!("{}", ntangent::obs::span::render_tree());
    let (phases, tiles, samples) = ntangent::obs::kernel_phase_totals();
    if !phases.is_empty() {
        println!("kernel phases ({samples} of {tiles} tiles sampled):");
        let total: u64 = phases.iter().map(|&(_, ns)| ns).sum();
        for (name, ns) in &phases {
            println!(
                "  {name:>10}  {:>10.3} ms  ({:>4.1}%)",
                *ns as f64 / 1e6,
                *ns as f64 / total.max(1) as f64 * 100.0
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------------- info

fn cmd_info(_raw: &[String]) -> Result<(), String> {
    println!("n-TangentProp tables");
    println!(
        "{:>4} {:>10} {:>14} {:>12}",
        "n", "p(n)", "Hardy-Raman.", "ops/layer"
    );
    let engine = NtpEngine::new(12);
    for n in 1..=12 {
        println!(
            "{n:>4} {:>10} {:>14.1} {:>12}",
            partition_count(n),
            hardy_ramanujan(n),
            engine.op_count(n, 1)
        );
    }
    match Runtime::cpu() {
        Ok(rt) => println!(
            "\nPJRT: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("\nPJRT unavailable: {e:#}"),
    }
    Ok(())
}
