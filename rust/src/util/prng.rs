//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through `splitmix64`, following the reference
//! implementations by Blackman & Vigna. Deterministic across platforms,
//! which the test-suite and the benchmark harness rely on.

/// A `xoshiro256**` generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. Uses rejection sampling to stay unbiased.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Prng::below(0)");
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box-Muller (polar/trig form).
    pub fn normal(&mut self) -> f64 {
        // Draw u in (0,1] to keep ln finite.
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Normal with given mean/stddev.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a vector with uniform `[lo, hi)` samples.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fill a vector with normal samples.
    pub fn normal_vec(&mut self, n: usize, mean: f64, std: f64) -> Vec<f64> {
        (0..n).map(|_| self.normal_with(mean, std)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Split off an independent generator (for per-worker streams).
    pub fn split(&mut self) -> Prng {
        Prng::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::seeded(42);
        let mut b = Prng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Prng::seeded(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Prng::seeded(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Prng::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Prng::seeded(4);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn split_streams_differ() {
        let mut rng = Prng::seeded(5);
        let mut a = rng.split();
        let mut b = rng.split();
        let same = (0..32).all(|_| a.next_u64() == b.next_u64());
        assert!(!same);
    }
}
