//! Wall-clock timing helpers for the benchmark harness.
//!
//! Mirrors the paper's methodology (§IV-B): use a monotonic performance
//! counter, run a warmup, and report per-trial averages.

use std::time::Instant;

/// Time a closure once; returns (seconds, result).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let start = Instant::now();
    let out = f();
    (start.elapsed().as_secs_f64(), out)
}

/// Run `warmup` untimed iterations, then `trials` timed iterations of `f`.
/// Returns the per-trial wall-clock seconds.
pub fn time_trials(warmup: usize, trials: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(trials);
    for _ in 0..trials {
        let start = Instant::now();
        f();
        out.push(start.elapsed().as_secs_f64());
    }
    out
}

/// A stopwatch that accumulates named segments; used to split the
/// forward / backward phases inside a single training step the way the
/// paper reports them separately (Figs 2 and 3).
#[derive(Default, Debug)]
pub struct SegmentClock {
    segments: Vec<(String, f64)>,
}

impl SegmentClock {
    /// Fresh clock with no segments.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and record it under `name` (accumulating).
    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        let dt = start.elapsed().as_secs_f64();
        self.add(name, dt);
        out
    }

    /// Add `dt` seconds to segment `name`.
    pub fn add(&mut self, name: &str, dt: f64) {
        if let Some(seg) = self.segments.iter_mut().find(|(n, _)| n == name) {
            seg.1 += dt;
        } else {
            self.segments.push((name.to_string(), dt));
        }
    }

    /// Total seconds recorded under `name` (0 if absent).
    pub fn get(&self, name: &str) -> f64 {
        self.segments
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| *t)
            .unwrap_or(0.0)
    }

    /// Sum of all segments.
    pub fn total(&self) -> f64 {
        self.segments.iter().map(|(_, t)| t).sum()
    }

    /// All `(name, seconds)` pairs in insertion order.
    pub fn segments(&self) -> &[(String, f64)] {
        &self.segments
    }

    /// Clear all segments.
    pub fn reset(&mut self) {
        self.segments.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trials_count() {
        let ts = time_trials(2, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(ts.len(), 5);
        assert!(ts.iter().all(|t| *t >= 0.0));
    }

    #[test]
    fn segment_clock_accumulates() {
        let mut clock = SegmentClock::new();
        clock.add("fwd", 1.0);
        clock.add("fwd", 0.5);
        clock.add("bwd", 2.0);
        assert_eq!(clock.get("fwd"), 1.5);
        assert_eq!(clock.get("bwd"), 2.0);
        assert_eq!(clock.get("missing"), 0.0);
        assert_eq!(clock.total(), 3.5);
        clock.reset();
        assert_eq!(clock.total(), 0.0);
    }

    #[test]
    fn measure_returns_value() {
        let mut clock = SegmentClock::new();
        let v = clock.measure("seg", || 42);
        assert_eq!(v, 42);
        assert!(clock.get("seg") >= 0.0);
    }
}
