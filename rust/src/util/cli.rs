//! A minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative option spec for usage rendering and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name (without the `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Whether the option consumes a value.
    pub takes_value: bool,
    /// Default value filled in when absent.
    pub default: Option<&'static str>,
}

impl Args {
    /// Parse raw arguments. `specs` defines which `--name`s take a value;
    /// unknown options are an error.
    pub fn parse(raw: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("--{name} requires a value"))?,
                    };
                    args.opts.insert(name, val);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        // Fill defaults.
        for spec in specs {
            if let Some(d) = spec.default {
                args.opts.entry(spec.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(args)
    }

    /// Whether `--name` was passed as a flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--name` (default-filled).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// `--name` parsed as an integer.
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| format!("--{name}: expected integer, got '{v}'")))
            .transpose()
    }

    /// `--name` parsed as a float.
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| format!("--{name}: expected number, got '{v}'")))
            .transpose()
    }

    /// Parse a comma-separated usize list, e.g. `--widths 16,24,64`.
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad integer '{p}'"))
                })
                .collect::<Result<Vec<usize>, String>>()
                .map(Some),
        }
    }

    /// Positional (non-option) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block from specs.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("{about}\n\nUSAGE: ntangent {cmd} [OPTIONS]\n\nOPTIONS:\n");
    for s in specs {
        let head = if s.takes_value {
            format!("--{} <value>", s.name)
        } else {
            format!("--{}", s.name)
        };
        let default = s.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        out.push_str(&format!("  {head:<26} {}{default}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", help: "derivatives", takes_value: true, default: Some("3") },
            OptSpec { name: "out", help: "output", takes_value: true, default: None },
            OptSpec { name: "verbose", help: "log more", takes_value: false, default: None },
        ]
    }

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&raw(&["--n", "5", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), Some(5));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = Args::parse(&raw(&["--out=x.csv"]), &specs()).unwrap();
        assert_eq!(a.get("out"), Some("x.csv"));
        assert_eq!(a.get_usize("n").unwrap(), Some(3)); // default
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&raw(&["--bogus"]), &specs()).is_err());
        assert!(Args::parse(&raw(&["--out"]), &specs()).is_err());
        assert!(Args::parse(&raw(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn list_parsing() {
        let sp = vec![OptSpec { name: "widths", help: "", takes_value: true, default: None }];
        let a = Args::parse(&raw(&["--widths", "16,24, 64"]), &sp).unwrap();
        assert_eq!(a.get_usize_list("widths").unwrap(), Some(vec![16, 24, 64]));
        let bad = Args::parse(&raw(&["--widths", "16,x"]), &sp).unwrap();
        assert!(bad.get_usize_list("widths").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("bench", "Run benchmarks", &specs());
        assert!(u.contains("--n"));
        assert!(u.contains("default: 3"));
    }
}
