//! A mini property-testing helper (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` randomly generated inputs; on
//! failure it reports the seed and case index so the exact counterexample
//! can be replayed deterministically.

use super::prng::Prng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base PRNG seed.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xA11CE }
    }
}

/// Run `prop` on `cfg.cases` generated inputs. `gen` builds an input from
/// the per-case RNG; `prop` returns `Err(msg)` to signal failure.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Prng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut master = Prng::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = master.split();
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={:#x}, case={case}): {msg}\ninput: {input:?}",
                cfg.seed
            );
        }
    }
}

/// Like [`check`] but with the default config.
pub fn quickcheck<T: std::fmt::Debug>(
    gen: impl FnMut(&mut Prng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(Config::default(), gen, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        quickcheck(
            |rng| rng.uniform_in(-10.0, 10.0),
            |x| {
                if x * x >= 0.0 {
                    Ok(())
                } else {
                    Err("square negative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            Config { cases: 16, seed: 7 },
            |rng| rng.uniform(),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    fn deterministic_generation() {
        let mut inputs_a = Vec::new();
        let mut inputs_b = Vec::new();
        let cfg = Config { cases: 8, seed: 99 };
        check(cfg, |rng| rng.next_u64(), |x| {
            inputs_a.push(*x);
            Ok(())
        });
        check(cfg, |rng| rng.next_u64(), |x| {
            inputs_b.push(*x);
            Ok(())
        });
        assert_eq!(inputs_a, inputs_b);
    }
}
