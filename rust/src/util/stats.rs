//! Summary statistics for benchmark reporting.

/// Summary of a sample of (timing) observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median.
    pub median: f64,
    /// 5th percentile.
    pub p5: f64,
    /// 95th percentile.
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p5: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of an already sorted slice, `q` in [0,100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least-squares fit `y = a + b x`; returns `(a, b, r2)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Fit `y ≈ c · r^x` by regressing `ln y` on `x`; returns `(c, r, r2)`.
///
/// Used by the benchmark harness to project autodiff runtimes beyond the
/// feasible range (the paper does the same for profiles 3 and 4).
pub fn exponential_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    let logs: Vec<f64> = ys.iter().map(|y| y.max(1e-300).ln()).collect();
    let (a, b, r2) = linear_fit(xs, &logs);
    (a.exp(), b.exp(), r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exponential_fit_recovers_growth() {
        let xs: Vec<f64> = (0..6).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * 2.0f64.powf(*x)).collect();
        let (c, r, r2) = exponential_fit(&xs, &ys);
        assert!((c - 3.0).abs() < 1e-9);
        assert!((r - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }
}
