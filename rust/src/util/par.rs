//! Deterministic parallel-execution substrate for the training path.
//!
//! Four primitives, shared by the sharded PINN objective
//! ([`crate::pinn::ParallelObjective`]) and the policy-aware optimizers
//! in [`crate::opt`]:
//!
//! - [`run_indexed`] — map a closure over task indices on scoped worker
//!   threads, returning results **in task order** regardless of which
//!   thread ran what.
//! - [`update_blocks`] — split several parallel mutable slices plus a
//!   shared slice into matching contiguous blocks and run an elementwise
//!   update per block (the Adam/SGD scoped block-split skeleton).
//! - [`tree_reduce`] — pairwise reduction whose tree shape depends only
//!   on the number of items, never on the thread count.
//! - [`det_dot`] / [`det_sum`] — reductions over fixed-size element
//!   chunks ([`REDUCE_CHUNK`]) combined with [`tree_reduce`], so the
//!   floating-point result is **identical for every
//!   [`ParallelPolicy`]**, serial included.
//!
//! The determinism argument is structural: every task/chunk performs the
//! exact same float operations wherever it runs, and the combination
//! order is a pure function of the task/chunk count. Threading only
//! changes scheduling, never arithmetic — which is what lets
//! `rust/tests/training_determinism.rs` demand *bitwise* equality
//! between serial and multi-threaded training.

use crate::ntp::ParallelPolicy;
use crate::simd::Isa;

/// Element count per partial-sum chunk in [`det_dot`] / [`det_sum`].
///
/// Fixed (not derived from the thread count) so the partials — and hence
/// the reduced result — are the same no matter how many workers computed
/// them.
pub const REDUCE_CHUNK: usize = 1024;

/// Elements per block when a policy splits an elementwise optimizer
/// update across threads ([`update_blocks`]) — the update is
/// memory-bound, so smaller blocks would be all spawn overhead.
pub const UPDATE_BLOCK: usize = 4096;

/// Split `M` equal-length mutable slices plus one shared read-only slice
/// into matching contiguous blocks and run `update` once per block —
/// inline when `policy`/size keep it serial, otherwise on scoped worker
/// threads (the trailing block runs on the calling thread).
///
/// This is the shared skeleton of the Adam/SGD policy updates: block
/// boundaries depend only on the length and the worker count, and every
/// block performs the same float ops wherever it runs, so the result is
/// **bitwise identical to the serial update for any worker count** (no
/// cross-element reductions exist anywhere in an elementwise update).
///
/// `update` receives each block's sub-slices in the same order as
/// `muts`; destructure with a slice pattern, e.g.
/// `let [m, v, th] = muts;` for `M = 3`.
pub fn update_blocks<const M: usize, F>(
    policy: ParallelPolicy,
    block: usize,
    muts: [&mut [f64]; M],
    shared: &[f64],
    update: F,
) where
    F: Fn(&mut [&mut [f64]; M], &[f64]) + Sync,
{
    let len = shared.len();
    for s in &muts {
        assert_eq!(s.len(), len, "update_blocks: slice length mismatch");
    }
    let workers = workers_for_tasks(policy, len.div_ceil(block.max(1)));
    if workers <= 1 {
        let mut all = muts;
        update(&mut all, shared);
        return;
    }
    let per = len.div_ceil(workers);
    std::thread::scope(|s| {
        let update = &update;
        let mut rest = muts;
        let mut g_rest = shared;
        while g_rest.len() > per {
            let (g0, g1) = g_rest.split_at(per);
            g_rest = g1;
            let mut heads: [&mut [f64]; M] = [(); M].map(|_| Default::default());
            for (h, r) in heads.iter_mut().zip(rest.iter_mut()) {
                let slice = std::mem::take(r);
                let (head, tail) = slice.split_at_mut(per);
                *h = head;
                *r = tail;
            }
            s.spawn(move || update(&mut heads, g0));
        }
        // The remainder runs inline on the calling thread.
        update(&mut rest, g_rest);
    });
}

/// Worker count for `tasks` coarse-grained tasks under `policy`.
///
/// Unlike [`ParallelPolicy::workers_for`] — which is tuned for per-*row*
/// work of a few microseconds and keeps small batches serial — each task
/// here is a whole shard evaluation (typically ≥ 100 µs), so `Auto`
/// engages whenever more than one task exists.
pub fn workers_for_tasks(policy: ParallelPolicy, tasks: usize) -> usize {
    policy.thread_cap().min(tasks.max(1))
}

/// Run `f(0), f(1), ..., f(n-1)` on up to `workers` scoped threads and
/// return the results in index order.
///
/// Indices are split into contiguous blocks, one per worker; block 0 runs
/// inline on the calling thread (so `workers` threads use exactly
/// `workers` cores). Each `f(i)` is a pure function of `i` as far as the
/// caller can observe, so the returned vector is independent of the
/// worker count.
pub fn run_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let w = workers.max(1).min(n.max(1));
    if w <= 1 {
        return (0..n).map(f).collect();
    }
    let per = n.div_ceil(w);
    let blocks: Vec<Vec<T>> = std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = (1..w)
            .filter_map(|k| {
                let lo = k * per;
                if lo >= n {
                    return None;
                }
                let hi = ((k + 1) * per).min(n);
                Some(s.spawn(move || (lo..hi).map(f).collect::<Vec<T>>()))
            })
            .collect();
        let mut blocks = Vec::with_capacity(w);
        blocks.push((0..per.min(n)).map(f).collect::<Vec<T>>());
        for h in handles {
            blocks.push(h.join().expect("par worker panicked"));
        }
        blocks
    });
    let mut out = Vec::with_capacity(n);
    for mut b in blocks {
        out.append(&mut b);
    }
    out
}

/// Deterministic pairwise tree reduction.
///
/// Adjacent pairs are combined layer by layer — `(0,1), (2,3), ...` —
/// until one value remains; a trailing odd item is carried up unchanged.
/// The tree shape (and therefore the floating-point result for
/// non-associative `f` like `+`) depends only on `items.len()`.
/// Returns `None` for an empty input.
pub fn tree_reduce<T>(items: Vec<T>, mut f: impl FnMut(T, T) -> T) -> Option<T> {
    let mut layer = items;
    if layer.is_empty() {
        return None;
    }
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        let mut it = layer.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(f(a, b)),
                None => next.push(a),
            }
        }
        layer = next;
    }
    layer.pop()
}

/// `Σ a[i]·b[i]` with a thread-count-invariant summation order.
///
/// Partial sums are taken over fixed [`REDUCE_CHUNK`]-element windows
/// (each window runs the dispatched fixed 4-lane reduction kernel,
/// [`Isa::dot`] — the lane pattern is part of the bitwise contract, so
/// every ISA produces the same partials) and combined with
/// [`tree_reduce`]; `policy` only decides how many threads compute the
/// windows, so every policy — `Serial` included — returns the same bits.
/// Threads only engage on large vectors (≥ ~64 chunks); smaller
/// reductions run inline because spawn cost would dominate — the result
/// is bit-identical either way.
pub fn det_dot(a: &[f64], b: &[f64], policy: ParallelPolicy) -> f64 {
    assert_eq!(a.len(), b.len(), "det_dot: length mismatch");
    let isa = Isa::active();
    det_chunked(a.len(), policy, |lo, hi| isa.dot(&a[lo..hi], &b[lo..hi]))
}

/// `Σ a[i]` with the same thread-count-invariant order as [`det_dot`].
pub fn det_sum(a: &[f64], policy: ParallelPolicy) -> f64 {
    let isa = Isa::active();
    det_chunked(a.len(), policy, |lo, hi| isa.sum(&a[lo..hi]))
}

/// Minimum chunk count before a reduction engages worker threads: below
/// this, a chunk's ~µs of multiply-adds is dwarfed by thread spawn cost,
/// so partials are computed inline (the *result* is identical either
/// way — the fixed chunking alone guarantees policy invariance).
const PAR_MIN_CHUNKS: usize = 64;

/// Shared chunked-partials skeleton of [`det_dot`] / [`det_sum`].
fn det_chunked<F>(len: usize, policy: ParallelPolicy, part: F) -> f64
where
    F: Fn(usize, usize) -> f64 + Sync,
{
    let n_chunks = len.div_ceil(REDUCE_CHUNK).max(1);
    let workers = if n_chunks >= PAR_MIN_CHUNKS {
        workers_for_tasks(policy, n_chunks)
    } else {
        1
    };
    let partials = run_indexed(n_chunks, workers, |c| {
        let lo = c * REDUCE_CHUNK;
        let hi = (lo + REDUCE_CHUNK).min(len);
        part(lo, hi)
    });
    tree_reduce(partials, |x, y| x + y).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn run_indexed_preserves_order() {
        for workers in [1usize, 2, 3, 8] {
            for n in [0usize, 1, 2, 7, 20] {
                let out = run_indexed(n, workers, |i| i * i);
                assert_eq!(out, (0..n).map(|i| i * i).collect::<Vec<_>>(), "w={workers} n={n}");
            }
        }
    }

    #[test]
    fn tree_reduce_shapes() {
        assert_eq!(tree_reduce(Vec::<i32>::new(), |a, b| a + b), None);
        assert_eq!(tree_reduce(vec![5], |a, b| a + b), Some(5));
        // Shape is observable through a non-associative combiner.
        let concat = |a: String, b: String| format!("({a}{b})");
        let items = |n: usize| (0..n).map(|i| i.to_string()).collect::<Vec<_>>();
        assert_eq!(tree_reduce(items(4), concat).unwrap(), "((01)(23))");
        assert_eq!(tree_reduce(items(5), concat).unwrap(), "(((01)(23))4)");
    }

    #[test]
    fn det_dot_is_policy_invariant_bitwise() {
        let mut rng = Prng::seeded(0x0DD);
        // 5000 elements stay below the threading threshold, 80_000 are
        // above it — both sizes must be policy-invariant bit for bit.
        for len in [5000usize, 80_000] {
            let a = rng.normal_vec(len, 0.0, 1.0);
            let b = rng.normal_vec(len, 0.0, 1.0);
            let want = det_dot(&a, &b, ParallelPolicy::Serial);
            for policy in [
                ParallelPolicy::Fixed(2),
                ParallelPolicy::Fixed(3),
                ParallelPolicy::Fixed(16),
                ParallelPolicy::Auto,
            ] {
                let got = det_dot(&a, &b, policy);
                assert_eq!(want.to_bits(), got.to_bits(), "len={len} {policy:?}");
            }
            // And it is actually a dot product.
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((want - naive).abs() <= 1e-9 * naive.abs().max(1.0));
        }
    }

    #[test]
    fn det_sum_handles_edges() {
        assert_eq!(det_sum(&[], ParallelPolicy::Fixed(4)), 0.0);
        assert_eq!(det_sum(&[3.5], ParallelPolicy::Auto), 3.5);
        let v = vec![1.0; 3000];
        assert_eq!(det_sum(&v, ParallelPolicy::Fixed(2)), 3000.0);
    }

    /// `update_blocks` is bitwise identical to the inline update for any
    /// worker count, including lengths straddling the block boundaries,
    /// and hands every slice's matching block to the closure.
    #[test]
    fn update_blocks_matches_serial_bitwise() {
        for len in [1usize, 100, 4096, 4097, 3 * 4096 + 17] {
            let mut rng = Prng::seeded(0xB10 + len as u64);
            let a0 = rng.normal_vec(len, 0.0, 1.0);
            let b0 = rng.normal_vec(len, 0.0, 1.0);
            let g = rng.normal_vec(len, 0.0, 1.0);
            // Serial oracle.
            let (mut a_want, mut b_want) = (a0.clone(), b0.clone());
            for i in 0..len {
                a_want[i] = 0.9 * a_want[i] + 0.1 * g[i];
                b_want[i] -= 0.5 * a_want[i];
            }
            for policy in [
                ParallelPolicy::Serial,
                ParallelPolicy::Fixed(2),
                ParallelPolicy::Fixed(5),
                ParallelPolicy::Auto,
            ] {
                let (mut a, mut b) = (a0.clone(), b0.clone());
                update_blocks(policy, UPDATE_BLOCK, [&mut a, &mut b], &g, |muts, gb| {
                    let [av, bv] = muts;
                    for i in 0..gb.len() {
                        av[i] = 0.9 * av[i] + 0.1 * gb[i];
                        bv[i] -= 0.5 * av[i];
                    }
                });
                assert_eq!(a, a_want, "{policy:?} len={len} first slice");
                assert_eq!(b, b_want, "{policy:?} len={len} second slice");
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn update_blocks_checks_lengths() {
        let mut a = vec![0.0; 3];
        update_blocks(
            ParallelPolicy::Serial,
            UPDATE_BLOCK,
            [&mut a],
            &[0.0; 4],
            |_, _| {},
        );
    }

    #[test]
    fn workers_for_tasks_clamps() {
        assert_eq!(workers_for_tasks(ParallelPolicy::Serial, 100), 1);
        assert_eq!(workers_for_tasks(ParallelPolicy::Fixed(4), 100), 4);
        assert_eq!(workers_for_tasks(ParallelPolicy::Fixed(4), 2), 2);
        assert_eq!(workers_for_tasks(ParallelPolicy::Fixed(0), 5), 1);
        assert_eq!(workers_for_tasks(ParallelPolicy::Fixed(4), 0), 1);
        // Auto engages for small task counts (coarse tasks), unlike the
        // per-row heuristic.
        assert!(workers_for_tasks(ParallelPolicy::Auto, 4) >= 1);
    }
}
