//! Substrates built from scratch: PRNG, statistics, timing, JSON, CSV,
//! CLI parsing and a mini property-testing helper.
//!
//! The offline crate registry in this environment only carries the `xla`
//! dependency closure, so the usual crates (`rand`, `serde`, `clap`,
//! `criterion`, `proptest`) are re-implemented here at the scale this
//! project needs.

pub mod cli;
pub mod csv;
pub mod json;
pub mod par;
pub mod prng;
pub mod ptest;
pub mod stats;
pub mod timer;

/// Relative/absolute closeness check used across tests.
///
/// Returns `true` when `|a - b| <= atol + rtol * max(|a|, |b|)`.
pub fn allclose(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    if a.is_nan() || b.is_nan() {
        return false;
    }
    (a - b).abs() <= atol + rtol * a.abs().max(b.abs())
}

/// Slice version of [`allclose`]; lengths must match.
pub fn allclose_slice(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| allclose(*x, *y, rtol, atol))
}

/// Maximum absolute elementwise difference between two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allclose_basic() {
        assert!(allclose(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!allclose(1.0, 1.1, 1e-9, 0.0));
        assert!(!allclose(f64::NAN, f64::NAN, 1.0, 1.0));
        assert!(allclose(0.0, 1e-12, 0.0, 1e-9));
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
