//! A small, complete JSON implementation (RFC 8259 subset).
//!
//! Used by the coordinator's wire protocol, checkpoints and the benchmark
//! reports. `serde`/`serde_json` are not available in the offline registry,
//! so this is written from scratch: a recursive-descent parser and a
//! writer. Numbers are `f64`; object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (insertion-ordered key/value pairs).
    Obj(Vec<(String, Json)>),
}

/// Errors produced by [`Json::parse`].
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset of the error.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------- parse

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ----------------------------------------------------------- accessors

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, if exact.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Decode an array of numbers.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    /// Build an object from pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    // ------------------------------------------------------------- writing

    /// Compact single-line encoding.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Sorted-key canonical form, handy for hashing/golden tests.
    pub fn canonical(&self) -> Json {
        match self {
            Json::Arr(items) => Json::Arr(items.iter().map(Json::canonical).collect()),
            Json::Obj(fields) => {
                let map: BTreeMap<String, Json> = fields
                    .iter()
                    .map(|(k, v)| (k.clone(), v.canonical()))
                    .collect();
                Json::Obj(map.into_iter().collect())
            }
            other => other.clone(),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no Inf/NaN; encode as null like most tolerant writers.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // 17 significant digits round-trips any f64.
        out.push_str(&format!("{:e}", x));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_preserves_values() {
        let cases = [
            r#"{"x":1,"y":[true,false,null],"z":{"w":"é"}}"#,
            r#"[0.1,1e-10,123456789,-0.5]"#,
            r#""quote \" backslash \\ tab \t""#,
        ];
        for case in cases {
            let v = Json::parse(case).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "case {case}");
        }
    }

    #[test]
    fn roundtrip_f64_precision() {
        let x = 0.12345678901234567;
        let v = Json::parse(&Json::Num(x).dump()).unwrap();
        assert_eq!(v.as_f64().unwrap(), x);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn errors_have_positions() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "xs": [1.5, 2.5]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("xs").unwrap().as_f64_vec().unwrap(), vec![1.5, 2.5]);
        assert!(v.get("missing").is_none());
        assert!(Json::Num(1.5).as_usize().is_none());
        assert!(Json::Num(-1.0).as_usize().is_none());
    }

    #[test]
    fn canonical_sorts_keys() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.canonical().dump(), r#"{"a":2,"b":1}"#);
    }
}
