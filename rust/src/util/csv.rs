//! CSV writing (and a small reader) for benchmark outputs under `results/`.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct Table {
    /// Column names.
    pub header: Vec<String>,
    /// Data rows (stringified cells).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given column names.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row of already-formatted cells; panics on arity mismatch.
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Push a row of f64 cells formatted with full precision.
    pub fn push_nums(&mut self, row: &[f64]) {
        self.push(row.iter().map(|x| format!("{x:.9e}")).collect());
    }

    /// Render as CSV text.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&escape_row(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&escape_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Write the CSV to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    /// Parse a CSV produced by [`Table::to_csv`] (simple quoting rules).
    pub fn load_str(text: &str) -> Option<Table> {
        let mut lines = text.lines();
        let header = parse_row(lines.next()?);
        let rows = lines
            .filter(|l| !l.is_empty())
            .map(parse_row)
            .collect::<Vec<_>>();
        for r in &rows {
            if r.len() != header.len() {
                return None;
            }
        }
        Some(Table { header, rows })
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Numeric column extraction.
    pub fn col_f64(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.col(name)?;
        self.rows.iter().map(|r| r[idx].parse().ok()).collect()
    }
}

fn escape_cell(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

fn escape_row(row: &[String]) -> String {
    row.iter().map(|c| escape_cell(c)).collect::<Vec<_>>().join(",")
}

fn parse_row(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' {
            in_quotes = true;
        } else if c == ',' {
            cells.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    cells.push(cur);
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        t.push(vec!["2".into(), "q\"uote".into()]);
        let parsed = Table::load_str(&t.to_csv()).unwrap();
        assert_eq!(parsed.header, t.header);
        assert_eq!(parsed.rows, t.rows);
    }

    #[test]
    fn numeric_columns() {
        let mut t = Table::new(&["n", "t"]);
        t.push_nums(&[1.0, 0.5]);
        t.push_nums(&[2.0, 0.25]);
        let parsed = Table::load_str(&t.to_csv()).unwrap();
        assert_eq!(parsed.col_f64("n").unwrap(), vec![1.0, 2.0]);
        assert_eq!(parsed.col_f64("t").unwrap(), vec![0.5, 0.25]);
        assert!(parsed.col("missing").is_none());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["only-one".into()]);
    }

    #[test]
    fn markdown_render() {
        let mut t = Table::new(&["x"]);
        t.push(vec!["1".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("| x |"));
        assert!(md.contains("| 1 |"));
    }
}
