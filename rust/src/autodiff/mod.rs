//! Tape-based reverse-mode automatic differentiation with *create-graph*
//! double-backward.
//!
//! This is the paper's **baseline**: computing `d^n/dx^n f` by applying
//! reverse-mode autodiff `n` times. Each `backward` pass appends the
//! gradient computation as new nodes to the same graph, so the gradient is
//! itself differentiable — exactly the mechanism behind
//! `torch.autograd.grad(..., create_graph=True)`. Repeating it `n` times
//! re-differentiates a graph that has already grown by a constant factor,
//! giving the exponential `O(c^n)` time/memory the paper measures
//! (Figs 1-5) and that n-TangentProp ([`crate::ntp`]) removes.
//!
//! Node ids are topological by construction (append-only arena), which the
//! evaluator and backward pass rely on.

pub mod backward;
pub mod eval;
pub mod higher;

use crate::ntp::activation::ActivationKind;
use crate::tensor::Tensor;

/// Index of a node in a [`Graph`].
pub type NodeId = usize;

/// Primitive operations. Every op's vector-Jacobian product is expressible
/// in terms of other ops in this set, which is what makes the tape
/// arbitrarily re-differentiable.
#[derive(Clone, Debug)]
pub enum Op {
    /// Placeholder bound at evaluation time to `inputs[slot]`.
    Input(usize),
    /// Embedded constant (not differentiated).
    Const(Tensor),
    /// Elementwise `a + b`.
    Add(NodeId, NodeId),
    /// Elementwise `a - b`.
    Sub(NodeId, NodeId),
    /// Elementwise `a * b`.
    Mul(NodeId, NodeId),
    /// Elementwise `a / b`.
    Div(NodeId, NodeId),
    /// Elementwise `-a`.
    Neg(NodeId),
    /// Elementwise `c · a`.
    Scale(NodeId, f64),
    /// Elementwise `a + c`.
    AddScalar(NodeId, f64),
    /// `A @ B`.
    MatMul(NodeId, NodeId),
    /// `A^T @ B` (fused; avoids materializing the transpose on backward).
    MatMulTN(NodeId, NodeId),
    /// `A @ B^T` (fused).
    MatMulNT(NodeId, NodeId),
    /// 2-D transpose.
    Transpose(NodeId),
    /// Elementwise activation derivative `σ^{(k)}(a)` for a registered
    /// [`ActivationKind`] (`k = 0` is the activation itself). Its VJP is
    /// `g · σ^{(k+1)}(a)`, which keeps the tape arbitrarily
    /// re-differentiable for *every* registered activation — the
    /// repeated-autodiff baseline is generic, not tanh-only.
    Act(NodeId, ActivationKind, usize),
    /// Elementwise integer power.
    PowI(NodeId, i32),
    /// `[B,F] + [F]` broadcast.
    AddBias(NodeId, NodeId),
    /// Total sum, result shape `[1]`.
    SumAll(NodeId),
    /// Column sums `[B,F] -> [F]`.
    SumAxis0(NodeId),
    /// Replicate `[F] -> [B,F]`.
    BroadcastRows(NodeId, usize),
    /// Fill `shape` with a `[1]` scalar.
    BroadcastScalar(NodeId, Vec<usize>),
}

/// A node: operation plus statically-known result shape.
#[derive(Clone, Debug)]
pub struct Node {
    /// The operation producing this node's value.
    pub op: Op,
    /// Statically-known result shape.
    pub shape: Vec<usize>,
}

/// An append-only computation graph ("tape").
///
/// The graph holds no interior mutability — building requires `&mut`,
/// while evaluation ([`Graph::eval`]) is `&self` and pure — so a built
/// graph is `Send + Sync` and can be evaluated concurrently from many
/// threads (the property the data-parallel training path leans on).
#[derive(Default, Debug)]
pub struct Graph {
    nodes: Vec<Node>,
    n_inputs: usize,
}

impl Graph {
    /// An empty graph.
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Number of nodes — the backend-independent size metric reported by
    /// the memory benchmarks.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// `true` when no nodes have been recorded.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The node behind `id`.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Result shape of node `id`.
    pub fn shape(&self, id: NodeId) -> &[usize] {
        &self.nodes[id].shape
    }

    /// Number of declared input slots.
    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    fn push(&mut self, op: Op, shape: Vec<usize>) -> NodeId {
        self.nodes.push(Node { op, shape });
        self.nodes.len() - 1
    }

    // ----------------------------------------------------------- builders

    /// Declare the next input slot with the given shape.
    pub fn input(&mut self, shape: &[usize]) -> NodeId {
        let slot = self.n_inputs;
        self.n_inputs += 1;
        self.push(Op::Input(slot), shape.to_vec())
    }

    /// Embed `t` as a constant node.
    pub fn constant(&mut self, t: Tensor) -> NodeId {
        let shape = t.shape().to_vec();
        self.push(Op::Const(t), shape)
    }

    /// A zero constant shaped like node `id`.
    pub fn zeros_like(&mut self, id: NodeId) -> NodeId {
        let shape = self.shape(id).to_vec();
        self.constant(Tensor::zeros(&shape))
    }

    fn binary_same_shape(&mut self, op: fn(NodeId, NodeId) -> Op, a: NodeId, b: NodeId) -> NodeId {
        assert_eq!(
            self.shape(a),
            self.shape(b),
            "shape mismatch: {:?} vs {:?}",
            self.shape(a),
            self.shape(b)
        );
        let shape = self.shape(a).to_vec();
        self.push(op(a, b), shape)
    }

    /// Elementwise `a + b` (same shape).
    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_same_shape(Op::Add, a, b)
    }

    /// Elementwise `a - b` (same shape).
    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_same_shape(Op::Sub, a, b)
    }

    /// Elementwise `a * b` (same shape).
    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_same_shape(Op::Mul, a, b)
    }

    /// Elementwise `a / b` (same shape).
    pub fn div(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_same_shape(Op::Div, a, b)
    }

    /// Elementwise `-a`.
    pub fn neg(&mut self, a: NodeId) -> NodeId {
        let shape = self.shape(a).to_vec();
        self.push(Op::Neg(a), shape)
    }

    /// Elementwise `c · a`.
    pub fn scale(&mut self, a: NodeId, c: f64) -> NodeId {
        let shape = self.shape(a).to_vec();
        self.push(Op::Scale(a, c), shape)
    }

    /// Elementwise `a + c`.
    pub fn add_scalar(&mut self, a: NodeId, c: f64) -> NodeId {
        let shape = self.shape(a).to_vec();
        self.push(Op::AddScalar(a, c), shape)
    }

    /// `A @ B` (`[m,k] x [k,n] -> [m,n]`).
    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa.len(), 2);
        assert_eq!(sb.len(), 2);
        assert_eq!(sa[1], sb[0], "matmul inner dims");
        self.push(Op::MatMul(a, b), vec![sa[0], sb[1]])
    }

    /// `A^T @ B` without materializing the transpose.
    pub fn matmul_tn(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa[0], sb[0], "matmul_tn inner dims");
        self.push(Op::MatMulTN(a, b), vec![sa[1], sb[1]])
    }

    /// `A @ B^T` without materializing the transpose.
    pub fn matmul_nt(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa[1], sb[1], "matmul_nt inner dims");
        self.push(Op::MatMulNT(a, b), vec![sa[0], sb[0]])
    }

    /// 2-D transpose.
    pub fn transpose(&mut self, a: NodeId) -> NodeId {
        let s = self.shape(a).to_vec();
        assert_eq!(s.len(), 2);
        self.push(Op::Transpose(a), vec![s[1], s[0]])
    }

    /// `σ_kind^{(k)}(a)` elementwise (`k = 0` applies the activation).
    pub fn act(&mut self, a: NodeId, kind: ActivationKind, k: usize) -> NodeId {
        let shape = self.shape(a).to_vec();
        self.push(Op::Act(a, kind, k), shape)
    }

    /// Convenience: `tanh(a)` (the paper's default activation).
    pub fn tanh(&mut self, a: NodeId) -> NodeId {
        self.act(a, ActivationKind::Tanh, 0)
    }

    /// Elementwise integer power `a^k`.
    pub fn powi(&mut self, a: NodeId, k: i32) -> NodeId {
        let shape = self.shape(a).to_vec();
        self.push(Op::PowI(a, k), shape)
    }

    /// `[B,F] + [F]` row-broadcast bias add.
    pub fn add_bias(&mut self, x: NodeId, bias: NodeId) -> NodeId {
        let (sx, sb) = (self.shape(x).to_vec(), self.shape(bias).to_vec());
        assert_eq!(sx.len(), 2);
        assert_eq!(sb.len(), 1);
        assert_eq!(sx[1], sb[0], "add_bias width");
        self.push(Op::AddBias(x, bias), sx)
    }

    /// Total sum as `[1]`.
    pub fn sum_all(&mut self, a: NodeId) -> NodeId {
        self.push(Op::SumAll(a), vec![1])
    }

    /// Column sums `[B,F] -> [F]`.
    pub fn sum_axis0(&mut self, a: NodeId) -> NodeId {
        let s = self.shape(a).to_vec();
        assert_eq!(s.len(), 2);
        self.push(Op::SumAxis0(a), vec![s[1]])
    }

    /// Replicate `[F] -> [B,F]`.
    pub fn broadcast_rows(&mut self, a: NodeId, b: usize) -> NodeId {
        let s = self.shape(a).to_vec();
        assert_eq!(s.len(), 1);
        self.push(Op::BroadcastRows(a, b), vec![b, s[0]])
    }

    /// Fill `shape` with a `[1]` scalar.
    pub fn broadcast_scalar(&mut self, a: NodeId, shape: &[usize]) -> NodeId {
        assert_eq!(self.shape(a), &[1], "broadcast_scalar expects [1]");
        self.push(Op::BroadcastScalar(a, shape.to_vec()), shape.to_vec())
    }

    /// Mean over all elements as `[1]`: `sum / numel`.
    pub fn mean_all(&mut self, a: NodeId) -> NodeId {
        let numel: usize = self.shape(a).iter().product();
        let s = self.sum_all(a);
        self.scale(s, 1.0 / numel as f64)
    }

    /// Mean of squares as `[1]` — the MSE building block of PINN losses.
    pub fn mean_square(&mut self, a: NodeId) -> NodeId {
        let sq = self.mul(a, a);
        self.mean_all(sq)
    }

    /// Operand ids of a node, in order.
    pub fn operands(&self, id: NodeId) -> Vec<NodeId> {
        match &self.nodes[id].op {
            Op::Input(_) | Op::Const(_) => vec![],
            Op::Neg(a)
            | Op::Scale(a, _)
            | Op::AddScalar(a, _)
            | Op::Transpose(a)
            | Op::Act(a, _, _)
            | Op::PowI(a, _)
            | Op::SumAll(a)
            | Op::SumAxis0(a)
            | Op::BroadcastRows(a, _)
            | Op::BroadcastScalar(a, _) => vec![*a],
            Op::Add(a, b)
            | Op::Sub(a, b)
            | Op::Mul(a, b)
            | Op::Div(a, b)
            | Op::MatMul(a, b)
            | Op::MatMulTN(a, b)
            | Op::MatMulNT(a, b)
            | Op::AddBias(a, b) => vec![*a, *b],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_topological() {
        let mut g = Graph::new();
        let x = g.input(&[2, 2]);
        let y = g.tanh(x);
        let z = g.add(x, y);
        assert!(x < y && y < z);
        assert_eq!(g.operands(z), vec![x, y]);
        assert_eq!(g.len(), 3);
    }

    #[test]
    fn shapes_propagate() {
        let mut g = Graph::new();
        let a = g.input(&[3, 4]);
        let b = g.input(&[4, 5]);
        let c = g.matmul(a, b);
        assert_eq!(g.shape(c), &[3, 5]);
        let t = g.transpose(c);
        assert_eq!(g.shape(t), &[5, 3]);
        let s = g.sum_all(t);
        assert_eq!(g.shape(s), &[1]);
        let m = g.mean_square(a);
        assert_eq!(g.shape(m), &[1]);
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn matmul_shape_check() {
        let mut g = Graph::new();
        let a = g.input(&[3, 4]);
        let b = g.input(&[5, 6]);
        g.matmul(a, b);
    }

    #[test]
    fn input_slots_increment() {
        let mut g = Graph::new();
        let a = g.input(&[1]);
        let b = g.input(&[2]);
        assert!(matches!(g.node(a).op, Op::Input(0)));
        assert!(matches!(g.node(b).op, Op::Input(1)));
        assert_eq!(g.n_inputs(), 2);
    }
}
