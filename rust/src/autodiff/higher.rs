//! Higher-order input derivatives by *repeated* reverse-mode autodiff —
//! the baseline the paper measures against (§III-A).
//!
//! For a network `u : [B,1] -> [B,1]` whose rows are independent samples,
//! `d/dx sum_b u_b` equals the per-sample derivative `du/dx` stacked over
//! the batch, so `n` applications of `backward(sum(·), x)` produce the
//! derivative stack `[u, u', ..., u^(n)]`. Every pass appends the gradient
//! graph of an already-grown graph: time and memory are exponential in `n`.

use super::{Graph, NodeId};
use crate::tensor::Tensor;

/// Build nodes for `[u, du/dx, ..., d^n u/dx^n]` by repeated backward.
///
/// `u` must have one output column and `x` one input column (per-sample
/// scalar-to-scalar), the PINN setting of the paper.
pub fn derivative_stack(g: &mut Graph, u: NodeId, x: NodeId, n: usize) -> Vec<NodeId> {
    assert_eq!(g.shape(u).len(), 2, "u must be [B,1]");
    assert_eq!(g.shape(u)[1], 1, "u must have a single output column");
    assert_eq!(g.shape(x)[1], 1, "x must have a single input column");
    let mut out = Vec::with_capacity(n + 1);
    out.push(u);
    let mut cur = u;
    for _ in 0..n {
        let s = g.sum_all(cur);
        cur = g.backward(s, &[x])[0];
        out.push(cur);
    }
    out
}

/// Build the node for the exact mixed partial `∂^α u` over a
/// multi-column input (`alpha[i]` = derivative order along input column
/// `i`) by `|α|` nested backward passes, extracting one gradient column
/// per differentiation.
///
/// This is the multivariate generalization of [`derivative_stack`] and
/// the nested-tape differential-testing baseline for the
/// directional-assembly path in [`crate::ntp::multi`]. Like the
/// univariate baseline, cost and graph size grow exponentially with
/// `|α|` — each backward re-differentiates an already-grown graph —
/// which is exactly what `ntangent bench operators` measures against.
pub fn mixed_partial(g: &mut Graph, u: NodeId, x: NodeId, alpha: &[usize]) -> NodeId {
    assert_eq!(g.shape(u)[1], 1, "u must have a single output column");
    let d = g.shape(x)[1];
    assert_eq!(alpha.len(), d, "multi-index arity must match the input dim");
    let mut cur = u;
    for (axis, &count) in alpha.iter().enumerate() {
        for _ in 0..count {
            let s = g.sum_all(cur);
            let grad = g.backward(s, &[x])[0]; // [B, d]
            cur = select_column(g, grad, axis, d);
        }
    }
    cur
}

/// Extract column `axis` of a `[B, d]` node as `[B, 1]` via a constant
/// basis-vector matmul (the tape has no slice op; the matmul keeps the
/// extraction arbitrarily re-differentiable).
fn select_column(g: &mut Graph, a: NodeId, axis: usize, d: usize) -> NodeId {
    let mut e = vec![0.0; d];
    e[axis] = 1.0;
    let basis = g.constant(Tensor::from_vec(e, &[d, 1]));
    g.matmul(a, basis)
}

/// Build nodes for the directional jet `[u, D_v u, ..., D_v^n u]` along
/// per-row directions `v: [B, d]` by repeated backward + contraction
/// with `v` — the nested-tape oracle for
/// [`crate::ntp::NtpEngine::forward_directional`].
pub fn directional_stack(
    g: &mut Graph,
    u: NodeId,
    x: NodeId,
    v: &Tensor,
    n: usize,
) -> Vec<NodeId> {
    assert_eq!(g.shape(u)[1], 1, "u must have a single output column");
    assert_eq!(v.shape(), g.shape(x), "one direction row per point row");
    let d = g.shape(x)[1];
    let vc = g.constant(v.clone());
    let ones = g.constant(Tensor::ones(&[d, 1]));
    let mut out = Vec::with_capacity(n + 1);
    out.push(u);
    let mut cur = u;
    for _ in 0..n {
        let s = g.sum_all(cur);
        let grad = g.backward(s, &[x])[0]; // [B, d]
        let prod = g.mul(grad, vc);
        cur = g.matmul(prod, ones); // per-row ∇u · v
        out.push(cur);
    }
    out
}

/// Graph sizes after each derivative order 0..=n — the memory-scaling
/// metric used by the `mem` benchmark (backend-independent analogue of the
/// paper's GPU OOM observation).
pub fn graph_growth(g: &mut Graph, u: NodeId, x: NodeId, n: usize) -> Vec<usize> {
    let mut sizes = Vec::with_capacity(n + 1);
    sizes.push(g.len());
    let mut cur = u;
    for _ in 0..n {
        let s = g.sum_all(cur);
        cur = g.backward(s, &[x])[0];
        sizes.push(g.len());
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::allclose_slice;

    /// u(x) = tanh(x) elementwise through a [B,1] pipe.
    fn tanh_graph() -> (Graph, NodeId, NodeId) {
        let mut g = Graph::new();
        let x = g.input(&[4, 1]);
        let u = g.tanh(x);
        (g, x, u)
    }

    #[test]
    fn stack_matches_closed_forms() {
        let (mut g, x, u) = tanh_graph();
        let stack = derivative_stack(&mut g, u, x, 3);
        let xv = Tensor::from_vec(vec![-1.0, -0.3, 0.4, 1.2], &[4, 1]);
        let vals = g.eval(&[xv.clone()], &stack);
        for (i, &z) in xv.data().iter().enumerate() {
            let t = z.tanh();
            let s = 1.0 - t * t;
            let expect = [t, s, -2.0 * t * s, -2.0 * s * (s - 2.0 * t * t)];
            for (order, e) in expect.iter().enumerate() {
                let got = vals.get(stack[order]).data()[i];
                assert!(
                    (got - e).abs() < 1e-10,
                    "order {order} sample {i}: {got} vs {e}"
                );
            }
        }
    }

    #[test]
    fn per_sample_independence() {
        // Derivatives computed on a batch must equal the ones computed on
        // each sample alone (the sum trick must not mix samples).
        let (mut g, x, u) = tanh_graph();
        let stack = derivative_stack(&mut g, u, x, 2);
        let xv = Tensor::from_vec(vec![0.1, 0.2, 0.3, 0.4], &[4, 1]);
        let batch = g.eval(&[xv.clone()], &stack);

        for i in 0..4 {
            let mut g1 = Graph::new();
            let x1 = g1.input(&[1, 1]);
            let u1 = g1.tanh(x1);
            let stack1 = derivative_stack(&mut g1, u1, x1, 2);
            let x1v = Tensor::from_vec(vec![xv.data()[i]], &[1, 1]);
            let single = g1.eval(&[x1v], &stack1);
            for order in 0..=2 {
                let a = batch.get(stack[order]).data()[i];
                let b = single.get(stack1[order]).data()[0];
                assert!((a - b).abs() < 1e-12, "order {order} sample {i}");
            }
        }
    }

    #[test]
    fn polynomial_high_order_is_exact() {
        // u = x^5 : u''''(x) = 120 x, u''''' = 120, u'''''' = 0.
        let mut g = Graph::new();
        let x = g.input(&[3, 1]);
        let u = g.powi(x, 5);
        let stack = derivative_stack(&mut g, u, x, 6);
        let xv = Tensor::from_vec(vec![1.0, -2.0, 0.5], &[3, 1]);
        let vals = g.eval(&[xv.clone()], &stack);
        let d4: Vec<f64> = xv.data().iter().map(|z| 120.0 * z).collect();
        assert!(allclose_slice(vals.get(stack[4]).data(), &d4, 1e-9, 1e-9));
        assert!(allclose_slice(
            vals.get(stack[5]).data(),
            &[120.0, 120.0, 120.0],
            1e-9,
            1e-9
        ));
        assert!(allclose_slice(vals.get(stack[6]).data(), &[0.0, 0.0, 0.0], 0.0, 1e-9));
    }

    /// `u(x, y) = x² y³`: every mixed partial is a closed-form monomial,
    /// including the total-order-5 constant `∂²x ∂³y u = 12` and the
    /// vanishing `∂³x u = 0`.
    #[test]
    fn mixed_partial_on_monomial_is_exact() {
        let mut g = Graph::new();
        let x = g.input(&[3, 2]);
        let e0 = g.constant(Tensor::from_vec(vec![1.0, 0.0], &[2, 1]));
        let e1 = g.constant(Tensor::from_vec(vec![0.0, 1.0], &[2, 1]));
        let x0 = g.matmul(x, e0);
        let x1 = g.matmul(x, e1);
        let a = g.powi(x0, 2);
        let b = g.powi(x1, 3);
        let u = g.mul(a, b);
        let d11 = mixed_partial(&mut g, u, x, &[1, 1]);
        let d23 = mixed_partial(&mut g, u, x, &[2, 3]);
        let d30 = mixed_partial(&mut g, u, x, &[3, 0]);
        let pts = Tensor::from_vec(vec![0.5, -1.0, 1.5, 2.0, -0.3, 0.7], &[3, 2]);
        let vals = g.eval(&[pts.clone()], &[d11, d23, d30]);
        for (i, row) in pts.data().chunks(2).enumerate() {
            let (xv, yv) = (row[0], row[1]);
            let want11 = 6.0 * xv * yv * yv; // ∂x∂y x²y³
            assert!(
                (vals.get(d11).data()[i] - want11).abs() < 1e-9,
                "d11 sample {i}"
            );
            assert!((vals.get(d23).data()[i] - 12.0).abs() < 1e-9, "d23 sample {i}");
            assert!(vals.get(d30).data()[i].abs() < 1e-9, "d30 sample {i}");
        }
    }

    /// The directional stack obeys the polarization expansion
    /// `D_v² u = v₀² u_xx + 2 v₀v₁ u_xy + v₁² u_yy` on `u = x² y³`.
    #[test]
    fn directional_stack_matches_polarized_mixed_partials() {
        let mut g = Graph::new();
        let x = g.input(&[2, 2]);
        let e0 = g.constant(Tensor::from_vec(vec![1.0, 0.0], &[2, 1]));
        let e1 = g.constant(Tensor::from_vec(vec![0.0, 1.0], &[2, 1]));
        let x0 = g.matmul(x, e0);
        let x1 = g.matmul(x, e1);
        let a = g.powi(x0, 2);
        let b = g.powi(x1, 3);
        let u = g.mul(a, b);
        let v = Tensor::from_vec(vec![1.0, 2.0, -0.5, 1.5], &[2, 2]);
        let stack = directional_stack(&mut g, u, x, &v, 2);
        let pts = Tensor::from_vec(vec![0.8, -0.6, 1.2, 0.4], &[2, 2]);
        let vals = g.eval(&[pts.clone()], &stack);
        for i in 0..2 {
            let (xv, yv) = (pts.data()[2 * i], pts.data()[2 * i + 1]);
            let (v0, v1) = (v.data()[2 * i], v.data()[2 * i + 1]);
            let u0 = xv * xv * yv * yv * yv;
            let d1 = v0 * 2.0 * xv * yv.powi(3) + v1 * 3.0 * xv * xv * yv * yv;
            let d2 = v0 * v0 * 2.0 * yv.powi(3)
                + 2.0 * v0 * v1 * 6.0 * xv * yv * yv
                + v1 * v1 * 6.0 * xv * xv * yv;
            assert!((vals.get(stack[0]).data()[i] - u0).abs() < 1e-10, "order 0 row {i}");
            assert!((vals.get(stack[1]).data()[i] - d1).abs() < 1e-9, "order 1 row {i}");
            assert!((vals.get(stack[2]).data()[i] - d2).abs() < 1e-9, "order 2 row {i}");
        }
    }

    #[test]
    fn growth_sizes_monotone() {
        let (mut g, x, u) = tanh_graph();
        let sizes = graph_growth(&mut g, u, x, 5);
        assert_eq!(sizes.len(), 6);
        assert!(sizes.windows(2).all(|w| w[1] > w[0]));
    }
}
