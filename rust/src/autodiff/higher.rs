//! Higher-order input derivatives by *repeated* reverse-mode autodiff —
//! the baseline the paper measures against (§III-A).
//!
//! For a network `u : [B,1] -> [B,1]` whose rows are independent samples,
//! `d/dx sum_b u_b` equals the per-sample derivative `du/dx` stacked over
//! the batch, so `n` applications of `backward(sum(·), x)` produce the
//! derivative stack `[u, u', ..., u^(n)]`. Every pass appends the gradient
//! graph of an already-grown graph: time and memory are exponential in `n`.

use super::{Graph, NodeId};

/// Build nodes for `[u, du/dx, ..., d^n u/dx^n]` by repeated backward.
///
/// `u` must have one output column and `x` one input column (per-sample
/// scalar-to-scalar), the PINN setting of the paper.
pub fn derivative_stack(g: &mut Graph, u: NodeId, x: NodeId, n: usize) -> Vec<NodeId> {
    assert_eq!(g.shape(u).len(), 2, "u must be [B,1]");
    assert_eq!(g.shape(u)[1], 1, "u must have a single output column");
    assert_eq!(g.shape(x)[1], 1, "x must have a single input column");
    let mut out = Vec::with_capacity(n + 1);
    out.push(u);
    let mut cur = u;
    for _ in 0..n {
        let s = g.sum_all(cur);
        cur = g.backward(s, &[x])[0];
        out.push(cur);
    }
    out
}

/// Graph sizes after each derivative order 0..=n — the memory-scaling
/// metric used by the `mem` benchmark (backend-independent analogue of the
/// paper's GPU OOM observation).
pub fn graph_growth(g: &mut Graph, u: NodeId, x: NodeId, n: usize) -> Vec<usize> {
    let mut sizes = Vec::with_capacity(n + 1);
    sizes.push(g.len());
    let mut cur = u;
    for _ in 0..n {
        let s = g.sum_all(cur);
        cur = g.backward(s, &[x])[0];
        sizes.push(g.len());
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::allclose_slice;

    /// u(x) = tanh(x) elementwise through a [B,1] pipe.
    fn tanh_graph() -> (Graph, NodeId, NodeId) {
        let mut g = Graph::new();
        let x = g.input(&[4, 1]);
        let u = g.tanh(x);
        (g, x, u)
    }

    #[test]
    fn stack_matches_closed_forms() {
        let (mut g, x, u) = tanh_graph();
        let stack = derivative_stack(&mut g, u, x, 3);
        let xv = Tensor::from_vec(vec![-1.0, -0.3, 0.4, 1.2], &[4, 1]);
        let vals = g.eval(&[xv.clone()], &stack);
        for (i, &z) in xv.data().iter().enumerate() {
            let t = z.tanh();
            let s = 1.0 - t * t;
            let expect = [t, s, -2.0 * t * s, -2.0 * s * (s - 2.0 * t * t)];
            for (order, e) in expect.iter().enumerate() {
                let got = vals.get(stack[order]).data()[i];
                assert!(
                    (got - e).abs() < 1e-10,
                    "order {order} sample {i}: {got} vs {e}"
                );
            }
        }
    }

    #[test]
    fn per_sample_independence() {
        // Derivatives computed on a batch must equal the ones computed on
        // each sample alone (the sum trick must not mix samples).
        let (mut g, x, u) = tanh_graph();
        let stack = derivative_stack(&mut g, u, x, 2);
        let xv = Tensor::from_vec(vec![0.1, 0.2, 0.3, 0.4], &[4, 1]);
        let batch = g.eval(&[xv.clone()], &stack);

        for i in 0..4 {
            let mut g1 = Graph::new();
            let x1 = g1.input(&[1, 1]);
            let u1 = g1.tanh(x1);
            let stack1 = derivative_stack(&mut g1, u1, x1, 2);
            let x1v = Tensor::from_vec(vec![xv.data()[i]], &[1, 1]);
            let single = g1.eval(&[x1v], &stack1);
            for order in 0..=2 {
                let a = batch.get(stack[order]).data()[i];
                let b = single.get(stack1[order]).data()[0];
                assert!((a - b).abs() < 1e-12, "order {order} sample {i}");
            }
        }
    }

    #[test]
    fn polynomial_high_order_is_exact() {
        // u = x^5 : u''''(x) = 120 x, u''''' = 120, u'''''' = 0.
        let mut g = Graph::new();
        let x = g.input(&[3, 1]);
        let u = g.powi(x, 5);
        let stack = derivative_stack(&mut g, u, x, 6);
        let xv = Tensor::from_vec(vec![1.0, -2.0, 0.5], &[3, 1]);
        let vals = g.eval(&[xv.clone()], &stack);
        let d4: Vec<f64> = xv.data().iter().map(|z| 120.0 * z).collect();
        assert!(allclose_slice(vals.get(stack[4]).data(), &d4, 1e-9, 1e-9));
        assert!(allclose_slice(
            vals.get(stack[5]).data(),
            &[120.0, 120.0, 120.0],
            1e-9,
            1e-9
        ));
        assert!(allclose_slice(vals.get(stack[6]).data(), &[0.0, 0.0, 0.0], 0.0, 1e-9));
    }

    #[test]
    fn growth_sizes_monotone() {
        let (mut g, x, u) = tanh_graph();
        let sizes = graph_growth(&mut g, u, x, 5);
        assert_eq!(sizes.len(), 6);
        assert!(sizes.windows(2).all(|w| w[1] > w[0]));
    }
}
