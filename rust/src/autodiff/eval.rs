//! Graph evaluation with per-node value caching.
//!
//! Only nodes reachable from the requested targets are computed — after
//! several `backward` passes the graph contains many nodes that a given
//! query does not need, and evaluating them would unfairly penalize the
//! autodiff baseline in the benchmarks.
//!
//! Evaluation is `&self` and allocates all state locally, so one graph
//! can be evaluated from many threads at once and the same `(inputs,
//! targets)` always produce the same bits — the property the
//! data-parallel trainer (one tape per collocation shard, evaluated on a
//! worker pool) is built on.

use super::{Graph, NodeId, Op};
use crate::tensor::Tensor;

/// Value store for one evaluation of a [`Graph`].
pub struct Values {
    slots: Vec<Option<Tensor>>,
}

impl Values {
    /// The computed value of node `id` (panics if it was unreachable).
    pub fn get(&self, id: NodeId) -> &Tensor {
        self.slots[id]
            .as_ref()
            .expect("node was not computed; was it in the reachable set?")
    }

    /// Move node `id`'s value out of the store.
    pub fn take(&mut self, id: NodeId) -> Tensor {
        self.slots[id].take().expect("node was not computed")
    }

    /// Number of materialized node values (memory metric).
    pub fn n_materialized(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

impl Graph {
    /// Evaluate `targets` given `inputs` (one tensor per input slot, in
    /// slot order). Returns a [`Values`] store from which any reachable
    /// node's value can be read.
    pub fn eval(&self, inputs: &[Tensor], targets: &[NodeId]) -> Values {
        assert_eq!(
            inputs.len(),
            self.n_inputs(),
            "eval: {} inputs provided, graph declares {}",
            inputs.len(),
            self.n_inputs()
        );
        // Mark reachable nodes (ids are topological: operands < node).
        let mut needed = vec![false; self.len()];
        let mut stack: Vec<NodeId> = targets.to_vec();
        while let Some(id) = stack.pop() {
            if needed[id] {
                continue;
            }
            needed[id] = true;
            for op in self.operands(id) {
                if !needed[op] {
                    stack.push(op);
                }
            }
        }

        let mut slots: Vec<Option<Tensor>> = vec![None; self.len()];
        for id in 0..self.len() {
            if !needed[id] {
                continue;
            }
            let v = self.eval_node(id, inputs, &slots);
            slots[id] = Some(v);
        }
        Values { slots }
    }

    fn eval_node(&self, id: NodeId, inputs: &[Tensor], slots: &[Option<Tensor>]) -> Tensor {
        let val = |nid: NodeId| -> &Tensor { slots[nid].as_ref().expect("operand missing") };
        match &self.node(id).op {
            Op::Input(slot) => {
                let t = &inputs[*slot];
                assert_eq!(
                    t.shape(),
                    self.shape(id),
                    "input slot {slot}: shape {:?} != declared {:?}",
                    t.shape(),
                    self.shape(id)
                );
                t.clone()
            }
            Op::Const(t) => t.clone(),
            Op::Add(a, b) => val(*a).add(val(*b)),
            Op::Sub(a, b) => val(*a).sub(val(*b)),
            Op::Mul(a, b) => val(*a).mul(val(*b)),
            Op::Div(a, b) => val(*a).div(val(*b)),
            Op::Neg(a) => val(*a).neg(),
            Op::Scale(a, c) => val(*a).scale(*c),
            Op::AddScalar(a, c) => val(*a).add_scalar(*c),
            Op::MatMul(a, b) => val(*a).matmul(val(*b)),
            Op::MatMulTN(a, b) => val(*a).matmul_tn(val(*b)),
            Op::MatMulNT(a, b) => val(*a).matmul_nt(val(*b)),
            Op::Transpose(a) => val(*a).transpose(),
            Op::Act(a, kind, k) => kind.deriv_tensor(val(*a), *k),
            Op::PowI(a, k) => val(*a).powi(*k),
            Op::AddBias(x, bias) => val(*x).add_bias(val(*bias)),
            Op::SumAll(a) => val(*a).sum_all(),
            Op::SumAxis0(a) => val(*a).sum_axis0(),
            Op::BroadcastRows(a, b) => val(*a).broadcast_rows(*b),
            Op::BroadcastScalar(a, shape) => val(*a).broadcast_scalar(shape),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluates_simple_expression() {
        let mut g = Graph::new();
        let x = g.input(&[2, 2]);
        let t = g.tanh(x);
        let y = g.add(x, t);
        let xv = Tensor::from_vec(vec![0.0, 1.0, -1.0, 0.5], &[2, 2]);
        let vals = g.eval(&[xv.clone()], &[y]);
        let expect = xv.add(&xv.tanh());
        assert_eq!(vals.get(y), &expect);
    }

    #[test]
    fn skips_unreachable_nodes() {
        let mut g = Graph::new();
        let x = g.input(&[2]);
        let _unused = g.tanh(x); // not requested
        let y = g.scale(x, 2.0);
        let vals = g.eval(&[Tensor::ones(&[2])], &[y]);
        assert_eq!(vals.n_materialized(), 2); // x and y only
    }

    #[test]
    #[should_panic(expected = "inputs provided")]
    fn input_arity_checked() {
        let mut g = Graph::new();
        let x = g.input(&[1]);
        g.eval(&[], &[x]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn input_shape_checked() {
        let mut g = Graph::new();
        let x = g.input(&[2, 2]);
        g.eval(&[Tensor::ones(&[3])], &[x]);
    }

    /// The tape and its value store are plain data: shareable across
    /// threads (compile-time assertion) with concurrent evaluations of
    /// one graph agreeing bitwise with the serial result.
    #[test]
    fn graph_evaluates_concurrently_and_identically() {
        fn assert_sync<T: Sync>() {}
        fn assert_send<T: Send>() {}
        assert_sync::<Graph>();
        assert_send::<Graph>();
        assert_send::<Values>();

        let mut g = Graph::new();
        let x = g.input(&[4, 1]);
        let t = g.tanh(x);
        let m = g.mul(t, x);
        let y = g.sum_all(m);
        let inputs: Vec<Vec<Tensor>> = (0..8)
            .map(|i| vec![Tensor::linspace(-1.0, 1.0 + i as f64 * 0.1, 4).reshape(&[4, 1])])
            .collect();
        let want: Vec<f64> = inputs.iter().map(|inp| g.eval(inp, &[y]).get(y).item()).collect();
        let got: Vec<f64> = std::thread::scope(|s| {
            let g = &g;
            let handles: Vec<_> = inputs
                .iter()
                .map(|inp| s.spawn(move || g.eval(inp, &[y]).get(y).item()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn composite_ops_match_tensor_api() {
        let mut g = Graph::new();
        let a = g.input(&[2, 3]);
        let b = g.input(&[3]);
        let biased = g.add_bias(a, b);
        let ms = g.mean_square(biased);
        let av = Tensor::linspace(0.0, 5.0, 6).reshape(&[2, 3]);
        let bv = Tensor::from_vec(vec![1.0, -1.0, 0.5], &[3]);
        let vals = g.eval(&[av.clone(), bv.clone()], &[ms]);
        let direct = av.add_bias(&bv);
        let expect = direct.mul(&direct).mean();
        assert!((vals.get(ms).item() - expect).abs() < 1e-12);
    }
}
