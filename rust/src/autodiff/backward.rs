//! Create-graph reverse-mode differentiation.
//!
//! `Graph::backward` appends the gradient computation of a (scalar) output
//! with respect to chosen nodes *as new graph nodes*, so gradients are
//! themselves differentiable — the mechanism PyTorch exposes as
//! `create_graph=True` and the reason repeated differentiation grows the
//! graph (and runtime) exponentially in the derivative order.
//!
//! Once appended, gradient nodes are ordinary tape nodes: the finished
//! tape stays `Send + Sync`, so the data-parallel trainer builds one
//! `backward`-augmented tape per collocation shard at construction time
//! and evaluates them concurrently ever after.

use super::{Graph, NodeId, Op};

impl Graph {
    /// Differentiate `y` with respect to each node in `wrt`, appending the
    /// gradient computation to the graph. `y` must be scalar-shaped `[1]`.
    ///
    /// Returns one gradient node per `wrt` entry (a zero constant when `y`
    /// does not depend on it). The graph can be differentiated again by
    /// calling `backward` on (functions of) the returned nodes.
    pub fn backward(&mut self, y: NodeId, wrt: &[NodeId]) -> Vec<NodeId> {
        assert_eq!(self.shape(y), &[1], "backward expects scalar output [1]");

        // Mark the subgraph that reaches y (only those need adjoints).
        let mut reachable = vec![false; self.len()];
        let mut stack = vec![y];
        while let Some(id) = stack.pop() {
            if reachable[id] {
                continue;
            }
            reachable[id] = true;
            for op in self.operands(id) {
                stack.push(op);
            }
        }

        let mut adjoint: Vec<Option<NodeId>> = vec![None; self.len()];
        let seed = self.constant(crate::tensor::Tensor::ones(&[1]));
        adjoint[y] = Some(seed);

        // Reverse topological sweep. New nodes appended during the sweep
        // have ids >= original length and are never revisited (they belong
        // to the *gradient* computation, differentiated on a later call).
        let upper = y + 1;
        for id in (0..upper).rev() {
            if !reachable[id] {
                continue;
            }
            let Some(g) = adjoint[id] else { continue };
            self.propagate(id, g, &mut adjoint);
        }

        wrt.iter()
            .map(|&w| adjoint[w].unwrap_or_else(|| self.zeros_like(w)))
            .collect()
    }

    /// Accumulate `delta` into `adjoint[target]`.
    fn accumulate(&mut self, adjoint: &mut [Option<NodeId>], target: NodeId, delta: NodeId) {
        adjoint[target] = Some(match adjoint[target] {
            None => delta,
            Some(existing) => self.add(existing, delta),
        });
    }

    /// Push the adjoint `g` of node `id` to its operands.
    fn propagate(&mut self, id: NodeId, g: NodeId, adjoint: &mut Vec<Option<NodeId>>) {
        // Clone the op descriptor to appease the borrow checker; it's tiny.
        let op = self.node(id).op.clone();
        match op {
            Op::Input(_) | Op::Const(_) => {}
            Op::Add(a, b) => {
                self.accumulate(adjoint, a, g);
                self.accumulate(adjoint, b, g);
            }
            Op::Sub(a, b) => {
                self.accumulate(adjoint, a, g);
                let ng = self.neg(g);
                self.accumulate(adjoint, b, ng);
            }
            Op::Mul(a, b) => {
                let ga = self.mul(g, b);
                self.accumulate(adjoint, a, ga);
                let gb = self.mul(g, a);
                self.accumulate(adjoint, b, gb);
            }
            Op::Div(a, b) => {
                // d(a/b)/da = 1/b ; d(a/b)/db = -a/b^2
                let ga = self.div(g, b);
                self.accumulate(adjoint, a, ga);
                let bb = self.mul(b, b);
                let gnum = self.mul(g, a);
                let frac = self.div(gnum, bb);
                let gb = self.neg(frac);
                self.accumulate(adjoint, b, gb);
            }
            Op::Neg(a) => {
                let ga = self.neg(g);
                self.accumulate(adjoint, a, ga);
            }
            Op::Scale(a, c) => {
                let ga = self.scale(g, c);
                self.accumulate(adjoint, a, ga);
            }
            Op::AddScalar(a, _) => {
                self.accumulate(adjoint, a, g);
            }
            Op::MatMul(a, b) => {
                // y = A B : gA = g B^T, gB = A^T g
                let ga = self.matmul_nt(g, b);
                self.accumulate(adjoint, a, ga);
                let gb = self.matmul_tn(a, g);
                self.accumulate(adjoint, b, gb);
            }
            Op::MatMulTN(a, b) => {
                // y = A^T B : gA = B g^T = matmul_nt(B, g), gB = A g
                let ga = self.matmul_nt(b, g);
                self.accumulate(adjoint, a, ga);
                let gb = self.matmul(a, g);
                self.accumulate(adjoint, b, gb);
            }
            Op::MatMulNT(a, b) => {
                // y = A B^T : gA = g B, gB = g^T A = matmul_tn(g, A)
                let ga = self.matmul(g, b);
                self.accumulate(adjoint, a, ga);
                let gb = self.matmul_tn(g, a);
                self.accumulate(adjoint, b, gb);
            }
            Op::Transpose(a) => {
                let ga = self.transpose(g);
                self.accumulate(adjoint, a, ga);
            }
            Op::Act(a, kind, k) => {
                // d σ^{(k)}(a) / da = σ^{(k+1)}(a): the next tower order is
                // itself an `Act` node, so the gradient stays exactly
                // re-differentiable for every registered activation.
                let next = self.act(a, kind, k + 1);
                let ga = self.mul(g, next);
                self.accumulate(adjoint, a, ga);
            }
            Op::PowI(a, k) => {
                // d a^k / da = k a^{k-1}
                let pow = self.powi(a, k - 1);
                let scaled = self.scale(pow, k as f64);
                let ga = self.mul(g, scaled);
                self.accumulate(adjoint, a, ga);
            }
            Op::AddBias(x, bias) => {
                self.accumulate(adjoint, x, g);
                let gb = self.sum_axis0(g);
                self.accumulate(adjoint, bias, gb);
            }
            Op::SumAll(a) => {
                let shape = self.shape(a).to_vec();
                let ga = self.broadcast_scalar(g, &shape);
                self.accumulate(adjoint, a, ga);
            }
            Op::SumAxis0(a) => {
                let b = self.shape(a)[0];
                let ga = self.broadcast_rows(g, b);
                self.accumulate(adjoint, a, ga);
            }
            Op::BroadcastRows(a, _) => {
                let ga = self.sum_axis0(g);
                self.accumulate(adjoint, a, ga);
            }
            Op::BroadcastScalar(a, _) => {
                let ga = self.sum_all(g);
                self.accumulate(adjoint, a, ga);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;
    use crate::util::{allclose_slice, ptest};

    /// Central finite-difference gradient of a scalar graph output wrt one
    /// input slot.
    fn fd_grad(
        g: &Graph,
        y: NodeId,
        inputs: &[Tensor],
        slot: usize,
        eps: f64,
    ) -> Vec<f64> {
        let mut grad = vec![0.0; inputs[slot].numel()];
        for i in 0..grad.len() {
            let mut plus = inputs.to_vec();
            plus[slot].data_mut()[i] += eps;
            let mut minus = inputs.to_vec();
            minus[slot].data_mut()[i] -= eps;
            let fp = g.eval(&plus, &[y]).get(y).item();
            let fm = g.eval(&minus, &[y]).get(y).item();
            grad[i] = (fp - fm) / (2.0 * eps);
        }
        grad
    }

    #[test]
    fn grad_of_square_sum() {
        // y = sum(x*x) => dy/dx = 2x
        let mut g = Graph::new();
        let x = g.input(&[3]);
        let sq = g.mul(x, x);
        let y = g.sum_all(sq);
        let grads = g.backward(y, &[x]);
        let xv = Tensor::from_vec(vec![1.0, -2.0, 0.5], &[3]);
        let vals = g.eval(&[xv], &[grads[0]]);
        assert_eq!(vals.get(grads[0]).data(), &[2.0, -4.0, 1.0]);
    }

    #[test]
    fn grad_zero_when_disconnected() {
        let mut g = Graph::new();
        let x = g.input(&[2]);
        let z = g.input(&[2]);
        let y = g.sum_all(x);
        let grads = g.backward(y, &[z]);
        let vals = g.eval(&[Tensor::ones(&[2]), Tensor::ones(&[2])], &[grads[0]]);
        assert_eq!(vals.get(grads[0]).data(), &[0.0, 0.0]);
    }

    #[test]
    fn all_ops_match_finite_differences() {
        ptest::check(
            ptest::Config { cases: 24, seed: 0xBEEF },
            |rng: &mut Prng| {
                let b = 1 + rng.below(3) as usize;
                let f = 1 + rng.below(3) as usize;
                let x = Tensor::rand_normal(&[b, f], 0.0, 0.8, rng);
                let w = Tensor::rand_normal(&[f, f], 0.0, 0.8, rng);
                let bias = Tensor::rand_normal(&[f], 0.0, 0.5, rng);
                (x, w, bias)
            },
            |(x, w, bias)| {
                // A scalar function that exercises most ops.
                let mut g = Graph::new();
                let xn = g.input(x.shape());
                let wn = g.input(w.shape());
                let bn = g.input(bias.shape());
                let h = g.matmul(xn, wn);
                let hb = g.add_bias(h, bn);
                let t = g.tanh(hb);
                let p = g.powi(t, 3);
                let tr = g.transpose(p);
                let tt = g.matmul_nt(tr, tr);
                let s1 = g.sum_all(tt);
                let diff = g.sub(t, hb);
                let sc = g.scale(diff, 0.3);
                let ms = g.mean_square(sc);
                let y = g.add(s1, ms);

                let inputs = vec![x.clone(), w.clone(), bias.clone()];
                let grads = g.backward(y, &[xn, wn, bn]);
                let vals = g.eval(&inputs, &grads);
                for (slot, gid) in grads.iter().enumerate() {
                    let analytic = vals.get(*gid).data().to_vec();
                    let numeric = fd_grad(&g, y, &inputs, slot, 1e-5);
                    if !allclose_slice(&analytic, &numeric, 1e-5, 1e-6) {
                        return Err(format!(
                            "slot {slot}: analytic {analytic:?} vs fd {numeric:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn second_derivative_via_double_backward() {
        // y = sum(x^3); dy/dx = 3x^2; d2y/dx2 (via backward of sum(dy/dx)) = 6x
        let mut g = Graph::new();
        let x = g.input(&[3]);
        let cube = g.powi(x, 3);
        let y = g.sum_all(cube);
        let g1 = g.backward(y, &[x])[0];
        let s1 = g.sum_all(g1);
        let g2 = g.backward(s1, &[x])[0];
        let xv = Tensor::from_vec(vec![1.0, 2.0, -1.5], &[3]);
        let vals = g.eval(&[xv], &[g1, g2]);
        assert_eq!(vals.get(g1).data(), &[3.0, 12.0, 6.75]);
        assert_eq!(vals.get(g2).data(), &[6.0, 12.0, -9.0]);
    }

    #[test]
    fn tanh_third_derivative_exact() {
        // tanh''' = -2 sech^2 (sech^2 - 2 tanh^2)... check against the
        // closed form evaluated directly.
        let mut g = Graph::new();
        let x = g.input(&[5]);
        let t = g.tanh(x);
        let y = g.sum_all(t);
        let g1 = g.backward(y, &[x])[0];
        let s1 = g.sum_all(g1);
        let g2 = g.backward(s1, &[x])[0];
        let s2 = g.sum_all(g2);
        let g3 = g.backward(s2, &[x])[0];
        let xv = Tensor::linspace(-1.5, 1.5, 5);
        let vals = g.eval(&[xv.clone()], &[g3]);
        let expect: Vec<f64> = xv
            .data()
            .iter()
            .map(|&z| {
                let t = z.tanh();
                let s = 1.0 - t * t; // sech^2
                // d3/dz3 tanh = -2 s (s - 2 t^2)  [standard identity]
                -2.0 * s * (s - 2.0 * t * t)
            })
            .collect();
        assert!(
            allclose_slice(vals.get(g3).data(), &expect, 1e-10, 1e-12),
            "{:?} vs {:?}",
            vals.get(g3).data(),
            expect
        );
    }

    #[test]
    fn backward_requires_scalar() {
        let mut g = Graph::new();
        let x = g.input(&[2, 2]);
        let y = g.tanh(x);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g2 = Graph::new();
            let x2 = g2.input(&[2, 2]);
            let y2 = g2.tanh(x2);
            g2.backward(y2, &[x2])
        }));
        assert!(result.is_err());
        let _ = (x, y);
    }

    #[test]
    fn graph_growth_is_exponential_in_derivative_order() {
        // The headline pathology: graph size multiplies with each backward.
        let mut g = Graph::new();
        let x = g.input(&[4, 1]);
        let w = g.constant(Tensor::ones(&[1, 8]));
        let w2 = g.constant(Tensor::ones(&[8, 1]));
        let h = g.matmul(x, w);
        let t = g.tanh(h);
        let u = g.matmul(t, w2);
        let mut sizes = vec![g.len()];
        let mut cur = u;
        for _ in 0..4 {
            let s = g.sum_all(cur);
            cur = g.backward(s, &[x])[0];
            sizes.push(g.len());
        }
        // Strictly growing and accelerating.
        let deltas: Vec<usize> = sizes.windows(2).map(|w| w[1] - w[0]).collect();
        for pair in deltas.windows(2) {
            assert!(pair[1] > pair[0], "growth not accelerating: {sizes:?}");
        }
    }
}
