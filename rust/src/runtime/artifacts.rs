//! Artifact registry: discover and describe the AOT bundle under
//! `artifacts/`, validated against the `manifest.json` the AOT step emits.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Metadata for one compiled artifact (one HLO text file).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// File name of the HLO text, relative to the artifacts dir.
    pub file: String,
    /// Derivative order this artifact computes (for `ntp_fwd_*`).
    pub n_derivs: Option<usize>,
    /// Compiled batch size (fixed shape).
    pub batch: Option<usize>,
    /// Flat parameter count expected in slot 0.
    pub n_params: Option<usize>,
    /// Network architecture, e.g. `[1, 24, 24, 24, 1]`.
    pub sizes: Vec<usize>,
}

/// The parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Artifact entries.
    pub specs: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<ArtifactManifest> {
        let root = Json::parse(text).context("manifest.json is not valid JSON")?;
        let arr = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts' array")?;
        let mut specs = Vec::new();
        for item in arr {
            let name = item
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let file = item
                .get("file")
                .and_then(Json::as_str)
                .context("artifact missing file")?
                .to_string();
            let sizes = item
                .get("sizes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            specs.push(ArtifactSpec {
                name,
                file,
                n_derivs: item.get("n_derivs").and_then(Json::as_usize),
                batch: item.get("batch").and_then(Json::as_usize),
                n_params: item.get("n_params").and_then(Json::as_usize),
                sizes,
            });
        }
        Ok(ArtifactManifest { dir: dir.to_path_buf(), specs })
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        match self.specs.iter().find(|s| s.name == name) {
            Some(s) => Ok(s),
            None => bail!(
                "artifact '{name}' not in manifest (have: {})",
                self.specs
                    .iter()
                    .map(|s| s.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "artifacts": [
            {"name": "ntp_fwd_d3", "file": "ntp_fwd_d3.hlo.txt",
             "n_derivs": 3, "batch": 256, "n_params": 1273,
             "sizes": [1, 24, 24, 24, 1]},
            {"name": "pinn_vg_k1", "file": "pinn_vg_k1.hlo.txt"}
        ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = ArtifactManifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.specs.len(), 2);
        let spec = m.get("ntp_fwd_d3").unwrap();
        assert_eq!(spec.n_derivs, Some(3));
        assert_eq!(spec.batch, Some(256));
        assert_eq!(spec.sizes, vec![1, 24, 24, 24, 1]);
        assert_eq!(
            m.path_of(spec),
            Path::new("/tmp/a").join("ntp_fwd_d3.hlo.txt")
        );
        // Optional fields absent → None.
        assert_eq!(m.get("pinn_vg_k1").unwrap().n_derivs, None);
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let m = ArtifactManifest::parse(Path::new("."), SAMPLE).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("ntp_fwd_d3"), "{err}");
    }

    #[test]
    fn bad_json_is_an_error() {
        assert!(ArtifactManifest::parse(Path::new("."), "{").is_err());
        assert!(ArtifactManifest::parse(Path::new("."), r#"{"x":1}"#).is_err());
    }
}
