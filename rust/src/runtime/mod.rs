//! PJRT runtime: load AOT-compiled HLO artifacts (produced once, at build
//! time, by `python/compile/aot.py`) and execute them from Rust.
//!
//! Python never runs on this path. The interchange format is HLO *text*
//! (not serialized `HloModuleProto`): jax ≥ 0.5 emits protos with 64-bit
//! instruction ids which the pinned `xla_extension` 0.5.1 rejects, while
//! the text parser reassigns ids and round-trips cleanly (see
//! `/opt/xla-example/README.md`).

pub mod artifacts;

pub use artifacts::{ArtifactManifest, ArtifactSpec};

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of PJRT devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled computation. Inputs/outputs are `f64` tensors; the AOT side
/// lowers everything with `jax_enable_x64` and `return_tuple=True`.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// The loaded artifact's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with `f64` tensor inputs; returns the tuple elements as
    /// `f64` tensors (shape recovered from the result literals).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let elems = out.to_tuple().context("untupling result")?;
        elems.iter().map(literal_to_tensor).collect()
    }
}

/// Convert an `f64` [`Tensor`] into an XLA literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    flat.reshape(&dims).context("reshaping literal")
}

/// Convert an XLA literal back into an `f64` [`Tensor`].
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("reading literal shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = l.ty().context("reading literal dtype")?;
    let data: Vec<f64> = match ty {
        xla::ElementType::F64 => l.to_vec::<f64>().context("reading f64 data")?,
        xla::ElementType::F32 => l
            .to_vec::<f32>()
            .context("reading f32 data")?
            .into_iter()
            .map(|x| x as f64)
            .collect(),
        other => bail!("unsupported artifact output dtype {other:?}"),
    };
    Ok(Tensor::from_vec(data, &dims))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::linspace(-1.0, 1.0, 6).reshape(&[2, 3]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back.shape(), t.shape());
        assert_eq!(back.data(), t.data());
    }

    // PJRT execution itself is covered by rust/tests/runtime_integration.rs
    // (requires `make artifacts`).
}
