//! n-TangentProp: exact higher-order input derivatives of feed-forward
//! networks in quasilinear time (the paper's contribution).
//!
//! Instead of re-differentiating the computational graph `n` times
//! (exponential — see [`crate::autodiff::higher`]), n-TangentProp carries
//! the derivative *channels* `y_i = d^i z/dx^i` through the network and
//! advances them across each activation with Faà di Bruno's formula
//! (eq. (5) of the paper), at a per-layer cost of `O(n·p(n))` tensor ops —
//! quasilinear in the derivative order by Hardy-Ramanujan.
//!
//! The activation is a pluggable [`ActivationKind`] (tanh, sine,
//! softplus, GELU) with an exact derivative tower each; every engine
//! dispatches on the model's activation at runtime.
//!
//! The batch axis is embarrassingly parallel (the bound is per point), so
//! [`NtpEngine`] carries a [`ParallelPolicy`] that chunks `forward_n`
//! across scoped threads — bitwise identical to the serial pass.
//!
//! The engine's hot path is a *fused element-tiled kernel*: the Faà di
//! Bruno tables are compiled once into a flat [`FdbProgram`], the combine
//! runs over L1-resident tiles of an interleaved channel layout, and the
//! affine step is a single stacked-channel GEMM (see
//! `docs/ARCHITECTURE.md`, "Kernel layout and memory traffic"); its hot
//! loops dispatch through the runtime-selected [`crate::simd`] kernels.
//! The pre-fusion pass is kept as `NtpEngine::forward_reference` behind
//! the `reference-oracle` cargo feature (differential oracle only).
//!
//! Multi-dimensional inputs are served by the same kernel through
//! **directional** jets: [`NtpEngine::forward_directional`] propagates
//! `d^k/dt^k f(x + t·v)` for per-row directions, and [`multi`] compiles
//! exact integer direction sets + rational recombination matrices that
//! assemble arbitrary mixed partials `∂^α u` from direction-stacked
//! batches ([`MultiJetEngine`]) — the substrate of the `pde` operator
//! subsystem. Beyond the exact plan's combinatorial envelope, [`stde`]
//! estimates operators *stochastically*: sparse random direction sets
//! sampled per step from a counter-based RNG, recombined into unbiased
//! Horvitz–Thompson estimates — the d=10–100 path.

pub mod activation;
pub mod bell;
pub mod forward;
pub mod multi;
pub mod partitions;
pub mod stde;
pub mod tape;

pub use activation::{
    ActivationKind, Gelu, Sine, SmoothActivation, Softplus, SoftplusTower, Tanh, TanhTower,
};
pub use bell::{bell_number, FaaDiBruno, FdbOp, FdbProgram, PowFill, Term};
pub use forward::{NtpEngine, ParallelPolicy};
pub use multi::{multi_indices, JetPlan, MultiJet, MultiJetEngine, RecombinationPlan};
pub use partitions::{hardy_ramanujan, partition_count, partitions, Partition};
pub use stde::{CounterRng, EstimatorMode, StdeConfig, StdeEngine, StdePlan};
