//! Integer partitions in the multiplicity representation used by
//! Faà di Bruno's formula (eq. (4) of the paper).
//!
//! A partition of `n` is a tuple `p = (p_1, ..., p_n)` with
//! `Σ_j j·p_j = n`; `p_j` counts the parts of size `j` and
//! `|p| = Σ_j p_j` is the number of parts. The number of partitions is the
//! partition function `p(n)`, which by Hardy-Ramanujan grows as
//! `O(e^√n / n)` — the source of the paper's quasilinear bound.

/// One partition of `n` in multiplicity form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Non-zero multiplicities as `(part_size j, count p_j)`, ascending `j`.
    pub parts: Vec<(usize, usize)>,
    /// `n = Σ j·p_j`.
    pub n: usize,
}

impl Partition {
    /// Number of parts `|p| = Σ p_j`.
    pub fn order(&self) -> usize {
        self.parts.iter().map(|(_, c)| c).sum()
    }

    /// Weighted sum `Σ j·p_j` (must equal `self.n`).
    pub fn weight(&self) -> usize {
        self.parts.iter().map(|(j, c)| j * c).sum()
    }
}

/// All partitions of `n` (multiplicity form). `partitions(0)` is the empty
/// partition; order of results is deterministic (lexicographic by largest
/// part descending).
pub fn partitions(n: usize) -> Vec<Partition> {
    let mut out = Vec::new();
    let mut current: Vec<usize> = Vec::new(); // part sizes, non-increasing
    fn rec(remaining: usize, max_part: usize, current: &mut Vec<usize>, out: &mut Vec<Partition>) {
        if remaining == 0 {
            // Convert part list to multiplicity form.
            let mut parts: Vec<(usize, usize)> = Vec::new();
            for &p in current.iter() {
                match parts.iter_mut().find(|(j, _)| *j == p) {
                    Some((_, c)) => *c += 1,
                    None => parts.push((p, 1)),
                }
            }
            parts.sort_by_key(|(j, _)| *j);
            let n = parts.iter().map(|(j, c)| j * c).sum();
            out.push(Partition { parts, n });
            return;
        }
        let cap = remaining.min(max_part);
        for part in (1..=cap).rev() {
            current.push(part);
            rec(remaining - part, part, current, out);
            current.pop();
        }
    }
    rec(n, n.max(1), &mut current, &mut out);
    out
}

/// The partition function `p(n) = |partitions(n)|`, computed by Euler's
/// pentagonal-number recurrence (cheap, exact for the `n` we use).
pub fn partition_count(n: usize) -> u64 {
    let mut p = vec![0u64; n + 1];
    p[0] = 1;
    for m in 1..=n {
        let mut acc: i128 = 0;
        let mut k: i64 = 1;
        loop {
            let g1 = (k * (3 * k - 1) / 2) as usize;
            let g2 = (k * (3 * k + 1) / 2) as usize;
            if g1 > m && g2 > m {
                break;
            }
            let sign: i128 = if k % 2 == 0 { -1 } else { 1 };
            if g1 <= m {
                acc += sign * p[m - g1] as i128;
            }
            if g2 <= m {
                acc += sign * p[m - g2] as i128;
            }
            k += 1;
        }
        p[m] = acc as u64;
    }
    p[n]
}

/// Hardy-Ramanujan asymptotic `p(n) ~ e^{π√(2n/3)} / (4n√3)` — used by the
/// benchmark reports to annotate the theoretical scaling curves.
pub fn hardy_ramanujan(n: usize) -> f64 {
    let nf = n as f64;
    (std::f64::consts::PI * (2.0 * nf / 3.0).sqrt()).exp() / (4.0 * nf * 3.0f64.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// OEIS A000041.
    const P: [u64; 21] = [
        1, 1, 2, 3, 5, 7, 11, 15, 22, 30, 42, 56, 77, 101, 135, 176, 231, 297, 385, 490, 627,
    ];

    #[test]
    fn partition_counts_match_oeis() {
        for (n, &expect) in P.iter().enumerate() {
            assert_eq!(partition_count(n), expect, "p({n})");
            assert_eq!(partitions(n).len() as u64, expect, "|partitions({n})|");
        }
    }

    #[test]
    fn partitions_have_correct_weight_and_are_unique() {
        for n in 1..=12 {
            let parts = partitions(n);
            for p in &parts {
                assert_eq!(p.weight(), n, "weight of {p:?}");
                assert_eq!(p.n, n);
                assert!(p.order() >= 1 && p.order() <= n);
                // multiplicity form: strictly ascending part sizes
                for w in p.parts.windows(2) {
                    assert!(w[0].0 < w[1].0);
                }
            }
            let mut keys: Vec<String> = parts.iter().map(|p| format!("{:?}", p.parts)).collect();
            keys.sort();
            keys.dedup();
            assert_eq!(keys.len(), parts.len(), "duplicates for n={n}");
        }
    }

    #[test]
    fn partitions_of_four_explicit() {
        // 4 = 4 = 3+1 = 2+2 = 2+1+1 = 1+1+1+1
        let parts = partitions(4);
        assert_eq!(parts.len(), 5);
        let orders: Vec<usize> = parts.iter().map(Partition::order).collect();
        let mut sorted = orders.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 2, 2, 3, 4]);
    }

    #[test]
    fn empty_partition_of_zero() {
        let parts = partitions(0);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].order(), 0);
    }

    #[test]
    fn hardy_ramanujan_is_same_order() {
        for n in [10usize, 16, 20] {
            let exact = partition_count(n) as f64;
            let approx = hardy_ramanujan(n);
            let ratio = approx / exact;
            assert!((0.5..2.0).contains(&ratio), "n={n} ratio={ratio}");
        }
    }
}
