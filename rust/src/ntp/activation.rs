//! Smooth activation functions with derivative *towers*: all of
//! `σ, σ', ..., σ^(n)` evaluated at once, which is what n-TangentProp
//! consumes at every layer (eq. (5b)).
//!
//! For tanh the tower is generated from the polynomial recurrence
//! `σ^(0) = t`, `σ^(k+1) = P_k'(t)·(1 - t²)` where `t = tanh(x)` — each
//! `σ^(k)` is a degree-`k+1` polynomial in `t`, so the whole tower costs
//! one `tanh` plus `O(n²)` multiply-adds per element.

use crate::tensor::Tensor;

/// A smooth (C^∞), parameter-free activation with computable derivative
/// towers — the class of activations the paper's theorem covers.
pub trait SmoothActivation: Send + Sync {
    fn name(&self) -> &'static str;

    /// σ(x) for a scalar.
    fn eval(&self, x: f64) -> f64;

    /// `[σ(x), σ'(x), ..., σ^(n)(x)]` for a scalar.
    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64>;

    /// Tower for every element of a tensor: returns `n+1` tensors shaped
    /// like `x`. Implementations should share work across orders.
    fn tower(&self, x: &Tensor, n: usize) -> Vec<Tensor> {
        // Generic fallback: scalar tower per element.
        let mut outs: Vec<Tensor> = (0..=n).map(|_| Tensor::zeros(x.shape())).collect();
        for (i, &v) in x.data().iter().enumerate() {
            let t = self.tower_scalar(v, n);
            for (k, out) in outs.iter_mut().enumerate() {
                out.data_mut()[i] = t[k];
            }
        }
        outs
    }
}

/// Coefficient table for the tanh derivative polynomials:
/// `σ^(k)(x) = P_k(tanh x)` with `P_0(t) = t`,
/// `P_{k+1}(t) = P_k'(t) · (1 - t²)`.
///
/// `coeffs[k][m]` is the coefficient of `t^m` in `P_k` (degree k+1).
#[derive(Clone, Debug)]
pub struct TanhTower {
    coeffs: Vec<Vec<f64>>,
}

impl TanhTower {
    pub fn new(n_max: usize) -> TanhTower {
        let mut coeffs: Vec<Vec<f64>> = Vec::with_capacity(n_max + 1);
        coeffs.push(vec![0.0, 1.0]); // P_0 = t
        for k in 0..n_max {
            let pk = &coeffs[k];
            // dP = P_k'(t)
            let mut dp = vec![0.0; pk.len().max(2) - 1];
            for (m, &c) in pk.iter().enumerate().skip(1) {
                dp[m - 1] = c * m as f64;
            }
            // P_{k+1} = dp * (1 - t^2)
            let mut next = vec![0.0; dp.len() + 2];
            for (m, &c) in dp.iter().enumerate() {
                next[m] += c;
                next[m + 2] -= c;
            }
            coeffs.push(next);
        }
        TanhTower { coeffs }
    }

    pub fn n_max(&self) -> usize {
        self.coeffs.len() - 1
    }

    /// Coefficients of `P_k` (low-to-high degree).
    pub fn poly(&self, k: usize) -> &[f64] {
        &self.coeffs[k]
    }

    /// Evaluate `P_k` at a scalar `t` (Horner).
    pub fn eval_poly(&self, k: usize, t: f64) -> f64 {
        let c = &self.coeffs[k];
        let mut acc = 0.0;
        for &ci in c.iter().rev() {
            acc = acc * t + ci;
        }
        acc
    }
}

/// tanh with a precomputed polynomial tower (the paper's activation).
#[derive(Clone, Debug)]
pub struct Tanh {
    table: TanhTower,
}

impl Tanh {
    pub fn new(n_max: usize) -> Tanh {
        Tanh { table: TanhTower::new(n_max) }
    }

    pub fn table(&self) -> &TanhTower {
        &self.table
    }
}

impl SmoothActivation for Tanh {
    fn name(&self) -> &'static str {
        "tanh"
    }

    fn eval(&self, x: f64) -> f64 {
        x.tanh()
    }

    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64> {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        let t = x.tanh();
        (0..=n).map(|k| self.table.eval_poly(k, t)).collect()
    }

    /// Vectorized tower: compute `tanh` once, then one contiguous Horner
    /// sweep per order (hot path of the n-TP forward — §Perf: the
    /// order-outer/element-inner layout lets the compiler vectorize the
    /// Horner recurrence across elements).
    fn tower(&self, x: &Tensor, n: usize) -> Vec<Tensor> {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        let t = x.tanh();
        let td = t.data();
        (0..=n)
            .map(|k| {
                let coeffs = self.table.poly(k);
                let mut out = Tensor::zeros(x.shape());
                let od = out.data_mut();
                match coeffs.len() {
                    0 => {}
                    1 => od.fill(coeffs[0]),
                    _ => {
                        let top = coeffs[coeffs.len() - 1];
                        for (o, &ti) in od.iter_mut().zip(td) {
                            let mut acc = top;
                            for &ci in coeffs[..coeffs.len() - 1].iter().rev() {
                                acc = acc * ti + ci;
                            }
                            *o = acc;
                        }
                    }
                }
                out
            })
            .collect()
    }
}

/// sin activation: `σ^(k)(x) = sin(x + kπ/2)`. Exact and cheap — used by
/// the test-suite as an independent oracle and useful for spectral-bias
/// experiments (SIREN-style PINNs).
#[derive(Clone, Copy, Debug, Default)]
pub struct Sine;

impl SmoothActivation for Sine {
    fn name(&self) -> &'static str {
        "sin"
    }

    fn eval(&self, x: f64) -> f64 {
        x.sin()
    }

    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64> {
        (0..=n)
            .map(|k| (x + k as f64 * std::f64::consts::FRAC_PI_2).sin())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    #[test]
    fn tanh_polynomials_low_orders() {
        let tt = TanhTower::new(3);
        assert_eq!(tt.poly(0), &[0.0, 1.0]); // t
        assert_eq!(tt.poly(1), &[1.0, 0.0, -1.0]); // 1 - t²
        assert_eq!(tt.poly(2), &[0.0, -2.0, 0.0, 2.0]); // -2t + 2t³
        assert_eq!(tt.poly(3), &[-2.0, 0.0, 8.0, 0.0, -6.0]); // -2 + 8t² - 6t⁴
    }

    #[test]
    fn tanh_tower_matches_finite_differences() {
        let act = Tanh::new(6);
        ptest::quickcheck(
            |rng| rng.uniform_in(-2.0, 2.0),
            |&x| {
                let tower = act.tower_scalar(x, 4);
                // FD each order from the previous one.
                let eps = 1e-6;
                for k in 1..=4 {
                    let up = act.tower_scalar(x + eps, k - 1)[k - 1];
                    let dn = act.tower_scalar(x - eps, k - 1)[k - 1];
                    let fd = (up - dn) / (2.0 * eps);
                    let scale = tower[k].abs().max(1.0);
                    if (tower[k] - fd).abs() > 2e-4 * scale {
                        return Err(format!("order {k} at x={x}: {} vs fd {fd}", tower[k]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn vectorized_tower_matches_scalar() {
        let act = Tanh::new(8);
        let x = Tensor::linspace(-2.5, 2.5, 11);
        let towers = act.tower(&x, 8);
        assert_eq!(towers.len(), 9);
        for (i, &xi) in x.data().iter().enumerate() {
            let scalar = act.tower_scalar(xi, 8);
            for k in 0..=8 {
                assert!(
                    (towers[k].data()[i] - scalar[k]).abs() < 1e-12,
                    "k={k} i={i}"
                );
            }
        }
    }

    #[test]
    fn sine_tower_rotates() {
        let s = Sine;
        let x = 0.3;
        let tower = s.tower_scalar(x, 4);
        assert!((tower[0] - x.sin()).abs() < 1e-15);
        assert!((tower[1] - x.cos()).abs() < 1e-15);
        assert!((tower[2] + x.sin()).abs() < 1e-15);
        assert!((tower[3] + x.cos()).abs() < 1e-15);
        assert!((tower[4] - x.sin()).abs() < 1e-15);
    }

    #[test]
    fn generic_tensor_tower_fallback_matches() {
        let s = Sine;
        let x = Tensor::linspace(-1.0, 1.0, 5);
        let towers = s.tower(&x, 3);
        for (i, &xi) in x.data().iter().enumerate() {
            let sc = s.tower_scalar(xi, 3);
            for k in 0..=3 {
                assert_eq!(towers[k].data()[i], sc[k]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "tower order")]
    fn tower_bounds_checked() {
        Tanh::new(2).tower_scalar(0.0, 3);
    }
}
