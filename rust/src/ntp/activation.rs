//! Smooth activation functions with derivative *towers*: all of
//! `σ, σ', ..., σ^(n)` evaluated at once, which is what n-TangentProp
//! consumes at every layer (eq. (5b)).
//!
//! The subsystem has two faces:
//!
//! - [`ActivationKind`] — a serializable, `Copy` identifier that travels
//!   with models (checkpoints, the wire protocol, CLI flags) and tags the
//!   generic activation op on the autodiff tape.
//! - [`SmoothActivation`] — the tower evaluator the n-TP hot path uses.
//!   [`ActivationKind::build_tower`] constructs one with tables
//!   precomputed up to `n_max`.
//!
//! Registered activations and their exact towers:
//!
//! | kind | tower |
//! |---|---|
//! | `tanh` | polynomial recurrence `P_0 = t`, `P_{k+1} = P_k'·(1−t²)` in `t = tanh x` |
//! | `sin`  | 4-cycle `σ^(k)(x) = sin(x + kπ/2)` |
//! | `softplus` | logistic polynomials `Q_1 = s`, `Q_{k+1} = Q_k'·(s−s²)` in `s = σ_logistic(x)` |
//! | `gelu` | Hermite tower from the Gaussian pdf: `gelu^{(k)} = (−1)^{k−1} φ(x)(He_k − He_{k−2})`, k ≥ 2 |

use crate::simd::Isa;
use crate::tensor::Tensor;

/// A smooth (C^∞), parameter-free activation with computable derivative
/// towers — the class of activations the paper's theorem covers.
pub trait SmoothActivation: Send + Sync {
    /// Canonical activation name (matches [`ActivationKind::name`]).
    fn name(&self) -> &'static str;

    /// σ(x) for a scalar.
    fn eval(&self, x: f64) -> f64;

    /// `[σ(x), σ'(x), ..., σ^(n)(x)]` for a scalar.
    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64>;

    /// Tower for every element of a tensor: returns `n+1` tensors shaped
    /// like `x`. Implementations should share work across orders.
    fn tower(&self, x: &Tensor, n: usize) -> Vec<Tensor> {
        // Generic fallback: scalar tower per element.
        let mut outs: Vec<Tensor> = (0..=n).map(|_| Tensor::zeros(x.shape())).collect();
        for (i, &v) in x.data().iter().enumerate() {
            let t = self.tower_scalar(v, n);
            for (k, out) in outs.iter_mut().enumerate() {
                out.data_mut()[i] = t[k];
            }
        }
        outs
    }

    /// Tower into caller-owned strided planes: `σ^{(k)}(xs[e])` is written
    /// to `out[k·stride + e]` for `k = 0..=n`, `e < xs.len()`.
    ///
    /// This is the fused n-TangentProp kernel's entry point: the caller
    /// hands a tile-local (L1-resident) workspace and the evaluation
    /// allocates nothing. Every element's value must be a function of that
    /// element alone (no cross-element coupling), which is what keeps
    /// row-chunked parallel execution bitwise identical to serial. The
    /// caller also picks the [`Isa`] for the polynomial/elementwise
    /// algebra of the sweep (the transcendental seeds stay scalar libm
    /// calls under every ISA) — results are bitwise ISA-independent.
    ///
    /// The default goes through [`SmoothActivation::tower_scalar`]
    /// (allocating one small vector per element); the registered
    /// activations override it with allocation-free sweeps.
    fn tower_into(&self, xs: &[f64], n: usize, out: &mut [f64], stride: usize, _isa: Isa) {
        assert!(stride >= xs.len(), "tower_into: stride shorter than the tile");
        assert!(out.len() >= n * stride + xs.len(), "tower_into: output too short");
        for (e, &v) in xs.iter().enumerate() {
            let t = self.tower_scalar(v, n);
            for (k, &tv) in t.iter().enumerate() {
                out[k * stride + e] = tv;
            }
        }
    }
}

// ---------------------------------------------------------------- registry

/// Serializable identifier of a registered activation. This is what
/// models, checkpoints, the wire protocol and the generic autodiff op
/// carry; towers are built from it on demand.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActivationKind {
    /// Hyperbolic tangent (the paper's activation).
    Tanh,
    /// Sine (SIREN-style spectral activation).
    Sine,
    /// Softplus `ln(1 + e^x)`.
    Softplus,
    /// Exact (erf-based) GELU `x·Φ(x)`.
    Gelu,
}

impl ActivationKind {
    /// Every registered activation, in registry order (see
    /// [`ActivationKind::index`]).
    pub const ALL: [ActivationKind; 4] = [
        ActivationKind::Tanh,
        ActivationKind::Sine,
        ActivationKind::Softplus,
        ActivationKind::Gelu,
    ];

    /// Canonical serialized name (checkpoints, wire protocol, CLI).
    pub fn name(self) -> &'static str {
        match self {
            ActivationKind::Tanh => "tanh",
            ActivationKind::Sine => "sin",
            ActivationKind::Softplus => "softplus",
            ActivationKind::Gelu => "gelu",
        }
    }

    /// Parse a serialized name (`"sine"` is accepted as an alias).
    pub fn from_name(s: &str) -> Option<ActivationKind> {
        match s {
            "tanh" => Some(ActivationKind::Tanh),
            "sin" | "sine" => Some(ActivationKind::Sine),
            "softplus" => Some(ActivationKind::Softplus),
            "gelu" => Some(ActivationKind::Gelu),
            _ => None,
        }
    }

    /// Stable position in [`ActivationKind::ALL`].
    pub fn index(self) -> usize {
        match self {
            ActivationKind::Tanh => 0,
            ActivationKind::Sine => 1,
            ActivationKind::Softplus => 2,
            ActivationKind::Gelu => 3,
        }
    }

    /// Build the tower evaluator with tables precomputed up to `n_max`.
    pub fn build_tower(self, n_max: usize) -> Box<dyn SmoothActivation> {
        match self {
            ActivationKind::Tanh => Box::new(Tanh::new(n_max)),
            ActivationKind::Sine => Box::new(Sine),
            ActivationKind::Softplus => Box::new(Softplus::new(n_max)),
            ActivationKind::Gelu => Box::new(Gelu),
        }
    }

    /// Elementwise σ(x) over a tensor.
    pub fn eval_tensor(self, x: &Tensor) -> Tensor {
        self.deriv_tensor(x, 0)
    }

    /// Elementwise σ^(k)(x) over a tensor — the evaluator behind the
    /// generic `Op::Act` autodiff primitive. Polynomial coefficient
    /// tables are memoized per thread (graphs evaluate the same orders
    /// every step), so each call is one transcendental sweep plus one
    /// vectorized Horner sweep.
    pub fn deriv_tensor(self, x: &Tensor, k: usize) -> Tensor {
        match self {
            ActivationKind::Tanh => {
                if k == 0 {
                    x.tanh()
                } else {
                    let t = x.tanh();
                    TANH_TABLE.with(|cell| {
                        let mut table = cell.borrow_mut();
                        if table.n_max() < k {
                            *table = TanhTower::new(k);
                        }
                        horner_tensor(&t, table.poly(k))
                    })
                }
            }
            ActivationKind::Sine => {
                let shift = k as f64 * std::f64::consts::FRAC_PI_2;
                x.map(|v| (v + shift).sin())
            }
            ActivationKind::Softplus => {
                if k == 0 {
                    x.map(softplus)
                } else {
                    let s = x.map(sigmoid);
                    SOFTPLUS_TABLE.with(|cell| {
                        let mut table = cell.borrow_mut();
                        if table.n_max() < k {
                            *table = SoftplusTower::new(k);
                        }
                        horner_tensor(&s, table.poly(k))
                    })
                }
            }
            ActivationKind::Gelu => x.map(|v| gelu_deriv_scalar(v, k)),
        }
    }
}

thread_local! {
    /// Per-thread memo of the tanh/softplus polynomial tables used by
    /// [`ActivationKind::deriv_tensor`], grown on demand — rebuilding the
    /// `O(k²)` tables on every `Op::Act` evaluation would dominate small
    /// graphs.
    static TANH_TABLE: std::cell::RefCell<TanhTower> =
        std::cell::RefCell::new(TanhTower::new(0));
    static SOFTPLUS_TABLE: std::cell::RefCell<SoftplusTower> =
        std::cell::RefCell::new(SoftplusTower::new(1));
}

/// Evaluate a polynomial (low-to-high coefficients) elementwise (Horner,
/// dispatched through the process-wide [`Isa`]).
fn horner_tensor(t: &Tensor, coeffs: &[f64]) -> Tensor {
    let mut out = Tensor::zeros(t.shape());
    Isa::active().horner_into(t.data(), coeffs, out.data_mut());
    out
}

// ------------------------------------------------------- polynomial towers

/// `P' · chain`, the shared recurrence step of the tanh and logistic
/// towers: differentiate `P` (in the substituted variable) and multiply by
/// the chain polynomial (`1 − t²` for tanh, `s − s²` for the logistic).
fn advance_poly(poly: &[f64], chain: &[f64]) -> Vec<f64> {
    // dP
    let mut dp = vec![0.0; poly.len().max(2) - 1];
    for (m, &c) in poly.iter().enumerate().skip(1) {
        dp[m - 1] = c * m as f64;
    }
    // dP * chain
    let mut next = vec![0.0; dp.len() + chain.len() - 1];
    for (i, &a) in dp.iter().enumerate() {
        for (j, &b) in chain.iter().enumerate() {
            next[i + j] += a * b;
        }
    }
    next
}

/// Coefficient table for the tanh derivative polynomials:
/// `σ^(k)(x) = P_k(tanh x)` with `P_0(t) = t`,
/// `P_{k+1}(t) = P_k'(t) · (1 - t²)`.
///
/// `coeffs[k][m]` is the coefficient of `t^m` in `P_k` (degree k+1).
#[derive(Clone, Debug)]
pub struct TanhTower {
    coeffs: Vec<Vec<f64>>,
}

impl TanhTower {
    /// Coefficient tables for orders `0..=n_max`.
    pub fn new(n_max: usize) -> TanhTower {
        let mut coeffs: Vec<Vec<f64>> = Vec::with_capacity(n_max + 1);
        coeffs.push(vec![0.0, 1.0]); // P_0 = t
        for k in 0..n_max {
            coeffs.push(advance_poly(&coeffs[k], &[1.0, 0.0, -1.0]));
        }
        TanhTower { coeffs }
    }

    /// Highest tabulated order.
    pub fn n_max(&self) -> usize {
        self.coeffs.len() - 1
    }

    /// Coefficients of `P_k` (low-to-high degree).
    pub fn poly(&self, k: usize) -> &[f64] {
        &self.coeffs[k]
    }

    /// Evaluate `P_k` at a scalar `t` (Horner).
    pub fn eval_poly(&self, k: usize, t: f64) -> f64 {
        let c = &self.coeffs[k];
        let mut acc = 0.0;
        for &ci in c.iter().rev() {
            acc = acc * t + ci;
        }
        acc
    }
}

/// tanh with a precomputed polynomial tower (the paper's activation).
#[derive(Clone, Debug)]
pub struct Tanh {
    table: TanhTower,
}

impl Tanh {
    /// Tower evaluator with tables up to `n_max`.
    pub fn new(n_max: usize) -> Tanh {
        Tanh { table: TanhTower::new(n_max) }
    }

    /// The underlying coefficient table.
    pub fn table(&self) -> &TanhTower {
        &self.table
    }
}

impl SmoothActivation for Tanh {
    fn name(&self) -> &'static str {
        "tanh"
    }

    fn eval(&self, x: f64) -> f64 {
        x.tanh()
    }

    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64> {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        let t = x.tanh();
        (0..=n).map(|k| self.table.eval_poly(k, t)).collect()
    }

    /// Vectorized tower: compute `tanh` once, then one contiguous Horner
    /// sweep per order (hot path of the n-TP forward — §Perf: the
    /// order-outer/element-inner layout lets the compiler vectorize the
    /// Horner recurrence across elements).
    fn tower(&self, x: &Tensor, n: usize) -> Vec<Tensor> {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        let t = x.tanh();
        (0..=n).map(|k| horner_tensor(&t, self.table.poly(k))).collect()
    }

    /// Allocation-free strided tower: plane 0 holds `tanh x` (= P₀) and
    /// doubles as the Horner input for planes 1..=n.
    fn tower_into(&self, xs: &[f64], n: usize, out: &mut [f64], stride: usize, isa: Isa) {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        assert!(stride >= xs.len(), "tower_into: stride shorter than the tile");
        assert!(out.len() >= n * stride + xs.len(), "tower_into: output too short");
        let m = xs.len();
        for (o, &x) in out[..m].iter_mut().zip(xs) {
            *o = x.tanh();
        }
        for k in 1..=n {
            let (t_plane, rest) = out.split_at_mut(stride);
            let off = (k - 1) * stride;
            isa.horner_into(&t_plane[..m], self.table.poly(k), &mut rest[off..off + m]);
        }
    }
}

/// sin activation: `σ^(k)(x) = sin(x + kπ/2)`. Exact and cheap — the
/// trivial 4-cycle tower, useful for spectral-bias experiments
/// (SIREN-style PINNs) and as an independent oracle in tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sine;

impl SmoothActivation for Sine {
    fn name(&self) -> &'static str {
        "sin"
    }

    fn eval(&self, x: f64) -> f64 {
        x.sin()
    }

    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64> {
        (0..=n)
            .map(|k| (x + k as f64 * std::f64::consts::FRAC_PI_2).sin())
            .collect()
    }

    /// Vectorized 4-cycle: `sin` and `cos` once, then sign flips.
    fn tower(&self, x: &Tensor, n: usize) -> Vec<Tensor> {
        let sin = x.map(f64::sin);
        let cos = x.map(f64::cos);
        (0..=n)
            .map(|k| match k % 4 {
                0 => sin.clone(),
                1 => cos.clone(),
                2 => sin.map(|v| -v),
                _ => cos.map(|v| -v),
            })
            .collect()
    }

    /// Allocation-free strided 4-cycle: `sin`/`cos` into planes 0/1, then
    /// sign-flipped copies for the higher orders.
    fn tower_into(&self, xs: &[f64], n: usize, out: &mut [f64], stride: usize, isa: Isa) {
        assert!(stride >= xs.len(), "tower_into: stride shorter than the tile");
        assert!(out.len() >= n * stride + xs.len(), "tower_into: output too short");
        let m = xs.len();
        for (o, &x) in out[..m].iter_mut().zip(xs) {
            *o = x.sin();
        }
        if n >= 1 {
            for (e, &x) in xs.iter().enumerate() {
                out[stride + e] = x.cos();
            }
        }
        for k in 2..=n {
            let (lo, hi) = out.split_at_mut(k * stride);
            let src_off = (k % 2) * stride;
            let src = &lo[src_off..src_off + m];
            if k % 4 < 2 {
                hi[..m].copy_from_slice(src);
            } else {
                isa.neg_into(&mut hi[..m], src);
            }
        }
    }
}

/// Numerically stable `ln(1 + e^x)`.
fn softplus(x: f64) -> f64 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// Logistic sigmoid `1 / (1 + e^{-x})`.
fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Coefficient table for the softplus derivative polynomials:
/// `softplus^(k)(x) = Q_k(s)` for `k ≥ 1` with `s = sigmoid(x)`,
/// `Q_1(s) = s`, `Q_{k+1}(s) = Q_k'(s) · (s − s²)` — the same recurrence
/// trick as [`TanhTower`], with the logistic chain polynomial.
#[derive(Clone, Debug)]
pub struct SoftplusTower {
    /// `coeffs[k]` holds `Q_k` for `k ≥ 1`; index 0 is unused (order 0 is
    /// softplus itself, which is not polynomial in `s`).
    coeffs: Vec<Vec<f64>>,
}

impl SoftplusTower {
    /// Coefficient tables for orders `1..=n_max`.
    pub fn new(n_max: usize) -> SoftplusTower {
        let mut coeffs: Vec<Vec<f64>> = Vec::with_capacity(n_max.max(1) + 1);
        coeffs.push(Vec::new()); // order 0 unused
        coeffs.push(vec![0.0, 1.0]); // Q_1 = s
        for k in 1..n_max {
            coeffs.push(advance_poly(&coeffs[k], &[0.0, 1.0, -1.0]));
        }
        SoftplusTower { coeffs }
    }

    /// Highest tabulated order.
    pub fn n_max(&self) -> usize {
        self.coeffs.len() - 1
    }

    /// Coefficients of `Q_k` for `k ≥ 1` (low-to-high degree).
    pub fn poly(&self, k: usize) -> &[f64] {
        assert!(k >= 1, "softplus order 0 is not polynomial in sigmoid");
        &self.coeffs[k]
    }

    /// Evaluate `Q_k` (`k ≥ 1`) at a scalar `s` (Horner).
    pub fn eval_poly(&self, k: usize, s: f64) -> f64 {
        let c = self.poly(k);
        let mut acc = 0.0;
        for &ci in c.iter().rev() {
            acc = acc * s + ci;
        }
        acc
    }
}

/// softplus with a precomputed logistic-polynomial tower.
#[derive(Clone, Debug)]
pub struct Softplus {
    table: SoftplusTower,
}

impl Softplus {
    /// Tower evaluator with tables up to `n_max`.
    pub fn new(n_max: usize) -> Softplus {
        Softplus { table: SoftplusTower::new(n_max.max(1)) }
    }

    /// The underlying coefficient table.
    pub fn table(&self) -> &SoftplusTower {
        &self.table
    }
}

impl SmoothActivation for Softplus {
    fn name(&self) -> &'static str {
        "softplus"
    }

    fn eval(&self, x: f64) -> f64 {
        softplus(x)
    }

    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64> {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        let s = sigmoid(x);
        (0..=n)
            .map(|k| if k == 0 { softplus(x) } else { self.table.eval_poly(k, s) })
            .collect()
    }

    /// Vectorized tower: one sigmoid per element, then a Horner sweep per
    /// order (order 0 gets the stable softplus directly).
    fn tower(&self, x: &Tensor, n: usize) -> Vec<Tensor> {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        let s = x.map(sigmoid);
        (0..=n)
            .map(|k| {
                if k == 0 {
                    x.map(softplus)
                } else {
                    horner_tensor(&s, self.table.poly(k))
                }
            })
            .collect()
    }

    /// Allocation-free strided tower: the sigmoid is staged in the *last*
    /// plane (consumed in place by its own final Horner sweep), the other
    /// orders Horner off it, and plane 0 gets the stable softplus.
    fn tower_into(&self, xs: &[f64], n: usize, out: &mut [f64], stride: usize, isa: Isa) {
        assert!(n <= self.table.n_max(), "tower order {n} > table n_max");
        assert!(stride >= xs.len(), "tower_into: stride shorter than the tile");
        assert!(out.len() >= n * stride + xs.len(), "tower_into: output too short");
        let m = xs.len();
        if n >= 1 {
            for (e, &x) in xs.iter().enumerate() {
                out[n * stride + e] = sigmoid(x);
            }
            for k in 1..n {
                let (lo, hi) = out.split_at_mut(n * stride);
                let off = k * stride;
                isa.horner_into(&hi[..m], self.table.poly(k), &mut lo[off..off + m]);
            }
            isa.horner_inplace(&mut out[n * stride..n * stride + m], self.table.poly(n));
        }
        for (o, &x) in out[..m].iter_mut().zip(xs) {
            *o = softplus(x);
        }
    }
}

/// Near-machine-precision `erf` via the cancellation-free confluent
/// hypergeometric series `erf(x) = (2x/√π) e^{−x²} Σ (2x²)^n / (2n+1)!!`
/// (all terms positive); `erfc(6) < 2·10⁻¹⁷`, so `|x| ≥ 6` saturates.
fn erf(x: f64) -> f64 {
    if x < 0.0 {
        return -erf(-x);
    }
    if x >= 6.0 {
        return 1.0;
    }
    let t = 2.0 * x * x;
    let mut term = 1.0;
    let mut sum = 1.0;
    let mut n = 1.0;
    while n < 300.0 {
        term *= t / (2.0 * n + 1.0);
        sum += term;
        if term < sum * 1e-17 {
            break;
        }
        n += 1.0;
    }
    (2.0 / std::f64::consts::PI.sqrt()) * x * (-x * x).exp() * sum
}

/// `gelu^(k)(x)` for the exact (erf-based) GELU `x·Φ(x)`:
/// `Φ^{(j)} = (−1)^{j−1} He_{j−1}(x) φ(x)` (probabilists' Hermite
/// polynomials from the Gaussian pdf `φ`), and Leibniz on `x·Φ` gives
/// `gelu^{(k)} = (−1)^{k−1} φ(x) (He_k(x) − He_{k−2}(x))` for `k ≥ 2`.
fn gelu_deriv_scalar(x: f64, k: usize) -> f64 {
    let sqrt_2 = std::f64::consts::SQRT_2;
    let sqrt_2pi = (2.0 * std::f64::consts::PI).sqrt();
    let cdf = 0.5 * (1.0 + erf(x / sqrt_2));
    match k {
        0 => x * cdf,
        _ => {
            let pdf = (-0.5 * x * x).exp() / sqrt_2pi;
            if k == 1 {
                cdf + x * pdf
            } else {
                // He_0..=He_k by the recurrence He_{m+1} = x·He_m − m·He_{m−1}.
                let mut he = vec![0.0; k + 1];
                he[0] = 1.0;
                he[1] = x;
                for m in 1..k {
                    he[m + 1] = x * he[m] - m as f64 * he[m - 1];
                }
                let sign = if (k - 1) % 2 == 0 { 1.0 } else { -1.0 };
                sign * pdf * (he[k] - he[k - 2])
            }
        }
    }
}

/// Elements per stack-resident `cdf`/`pdf` staging block of the strided
/// GELU tower — matches the fused kernel's 128-element tile, so the hot
/// path runs exactly one block per call.
const GELU_BLOCK: usize = 128;

/// Exact (erf-based) GELU `x·Φ(x)` with the Hermite-polynomial tower.
#[derive(Clone, Copy, Debug, Default)]
pub struct Gelu;

impl SmoothActivation for Gelu {
    fn name(&self) -> &'static str {
        "gelu"
    }

    fn eval(&self, x: f64) -> f64 {
        gelu_deriv_scalar(x, 0)
    }

    fn tower_scalar(&self, x: f64, n: usize) -> Vec<f64> {
        let sqrt_2 = std::f64::consts::SQRT_2;
        let sqrt_2pi = (2.0 * std::f64::consts::PI).sqrt();
        let cdf = 0.5 * (1.0 + erf(x / sqrt_2));
        let pdf = (-0.5 * x * x).exp() / sqrt_2pi;
        let mut out = Vec::with_capacity(n + 1);
        out.push(x * cdf);
        if n >= 1 {
            out.push(cdf + x * pdf);
        }
        if n >= 2 {
            let mut he = vec![0.0; n + 1];
            he[0] = 1.0;
            he[1] = x;
            for m in 1..n {
                he[m + 1] = x * he[m] - m as f64 * he[m - 1];
            }
            for k in 2..=n {
                let sign = if (k - 1) % 2 == 0 { 1.0 } else { -1.0 };
                out.push(sign * pdf * (he[k] - he[k - 2]));
            }
        }
        out
    }

    /// Allocation-free strided tower: the transcendental seeds (`Φ` via
    /// `erf`, `φ` via `exp`) are computed scalar into small stack blocks,
    /// then the Hermite recurrence is rolled across elements by the
    /// dispatched [`Isa::gelu_tail`] kernel (three registers `He_{k−2},
    /// He_{k−1}, He_k` per lane) — the same arithmetic as
    /// [`Gelu::tower_scalar`], no per-element vector.
    fn tower_into(&self, xs: &[f64], n: usize, out: &mut [f64], stride: usize, isa: Isa) {
        assert!(stride >= xs.len(), "tower_into: stride shorter than the tile");
        assert!(out.len() >= n * stride + xs.len(), "tower_into: output too short");
        let sqrt_2 = std::f64::consts::SQRT_2;
        let sqrt_2pi = (2.0 * std::f64::consts::PI).sqrt();
        let mut cdf = [0.0f64; GELU_BLOCK];
        let mut pdf = [0.0f64; GELU_BLOCK];
        let mut base = 0;
        while base < xs.len() {
            let len = GELU_BLOCK.min(xs.len() - base);
            let xb = &xs[base..base + len];
            for (o, &x) in cdf[..len].iter_mut().zip(xb) {
                *o = 0.5 * (1.0 + erf(x / sqrt_2));
            }
            if n >= 1 {
                for (o, &x) in pdf[..len].iter_mut().zip(xb) {
                    *o = (-0.5 * x * x).exp() / sqrt_2pi;
                }
            }
            // out[base..]: plane k of block element e sits at
            // k·stride + (base + e), i.e. k·stride + e of the offset view.
            isa.gelu_tail(xb, &cdf[..len], &pdf[..len], n, &mut out[base..], stride);
            base += len;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    #[test]
    fn tanh_polynomials_low_orders() {
        let tt = TanhTower::new(3);
        assert_eq!(tt.poly(0), &[0.0, 1.0]); // t
        assert_eq!(tt.poly(1), &[1.0, 0.0, -1.0]); // 1 - t²
        assert_eq!(tt.poly(2), &[0.0, -2.0, 0.0, 2.0]); // -2t + 2t³
        assert_eq!(tt.poly(3), &[-2.0, 0.0, 8.0, 0.0, -6.0]); // -2 + 8t² - 6t⁴
    }

    #[test]
    fn softplus_polynomials_low_orders() {
        let st = SoftplusTower::new(3);
        assert_eq!(st.poly(1), &[0.0, 1.0]); // s
        assert_eq!(st.poly(2), &[0.0, 1.0, -1.0]); // s - s²
        assert_eq!(st.poly(3), &[0.0, 1.0, -3.0, 2.0]); // s - 3s² + 2s³
    }

    /// Central finite differences against every registered tower, orders
    /// 1..=6 — each order checked against an FD of the previous one.
    #[test]
    fn towers_match_finite_differences_for_all_kinds() {
        for kind in ActivationKind::ALL {
            let act = kind.build_tower(6);
            ptest::check(
                ptest::Config { cases: 48, seed: 0x70E5 + kind.index() as u64 },
                |rng| rng.uniform_in(-2.0, 2.0),
                |&x| {
                    let tower = act.tower_scalar(x, 6);
                    let eps = 1e-6;
                    for k in 1..=6 {
                        let up = act.tower_scalar(x + eps, k - 1)[k - 1];
                        let dn = act.tower_scalar(x - eps, k - 1)[k - 1];
                        let fd = (up - dn) / (2.0 * eps);
                        let scale = tower[k].abs().max(1.0);
                        if (tower[k] - fd).abs() > 5e-4 * scale {
                            return Err(format!(
                                "{} order {k} at x={x}: {} vs fd {fd}",
                                kind.name(),
                                tower[k]
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn vectorized_towers_match_scalar_for_all_kinds() {
        let x = Tensor::linspace(-2.5, 2.5, 11);
        for kind in ActivationKind::ALL {
            let act = kind.build_tower(8);
            let towers = act.tower(&x, 8);
            assert_eq!(towers.len(), 9);
            for (i, &xi) in x.data().iter().enumerate() {
                let scalar = act.tower_scalar(xi, 8);
                for k in 0..=8 {
                    assert!(
                        (towers[k].data()[i] - scalar[k]).abs() < 1e-12,
                        "{} k={k} i={i}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn deriv_tensor_matches_towers() {
        let x = Tensor::linspace(-2.0, 2.0, 9);
        for kind in ActivationKind::ALL {
            let act = kind.build_tower(5);
            for k in 0..=5 {
                let d = kind.deriv_tensor(&x, k);
                for (i, &xi) in x.data().iter().enumerate() {
                    let expect = act.tower_scalar(xi, k)[k];
                    assert!(
                        (d.data()[i] - expect).abs() < 1e-12,
                        "{} k={k} i={i}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn sine_tower_rotates() {
        let s = Sine;
        let x = 0.3;
        let tower = s.tower_scalar(x, 4);
        assert!((tower[0] - x.sin()).abs() < 1e-15);
        assert!((tower[1] - x.cos()).abs() < 1e-15);
        assert!((tower[2] + x.sin()).abs() < 1e-15);
        assert!((tower[3] + x.cos()).abs() < 1e-15);
        assert!((tower[4] - x.sin()).abs() < 1e-15);
    }

    #[test]
    fn gelu_low_order_closed_forms() {
        // gelu'' = φ(x)(2 − x²), gelu''' = φ(x)(x³ − 4x).
        let sqrt_2pi = (2.0 * std::f64::consts::PI).sqrt();
        for &x in &[-1.3, -0.2, 0.0, 0.7, 2.1] {
            let pdf = (-0.5 * x * x).exp() / sqrt_2pi;
            let t = Gelu.tower_scalar(x, 3);
            assert!((t[2] - pdf * (2.0 - x * x)).abs() < 1e-12, "x={x}");
            assert!((t[3] - pdf * (x * x * x - 4.0 * x)).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn erf_reference_values() {
        // erf(1) and erf(2) to published 15-digit accuracy.
        assert!((erf(1.0) - 0.842_700_792_949_715).abs() < 1e-14);
        assert!((erf(2.0) - 0.995_322_265_018_953).abs() < 1e-14);
        assert!((erf(-1.0) + erf(1.0)).abs() < 1e-16);
        assert_eq!(erf(7.0), 1.0);
    }

    #[test]
    fn registry_roundtrips_names() {
        for kind in ActivationKind::ALL {
            assert_eq!(ActivationKind::from_name(kind.name()), Some(kind));
            assert_eq!(kind.build_tower(3).name(), kind.name());
        }
        assert_eq!(ActivationKind::from_name("sine"), Some(ActivationKind::Sine));
        assert_eq!(ActivationKind::from_name("relu"), None);
    }

    /// The strided `tower_into` planes (fused-kernel entry point) match
    /// the scalar towers for every registered activation, including
    /// partial tiles (`xs.len() < stride`) and every order 0..=n_max.
    /// (Scalar ISA here; the scalar≡vector contract is covered by
    /// `rust/tests/simd_dispatch.rs`.)
    #[test]
    fn strided_tower_into_matches_scalar_for_all_kinds() {
        let xs: Vec<f64> = (0..11).map(|i| -2.5 + 0.5 * i as f64).collect();
        let stride = 16; // ragged tile: stride > element count
        for kind in ActivationKind::ALL {
            let act = kind.build_tower(8);
            for n in [0usize, 1, 2, 5, 8] {
                let mut out = vec![f64::NAN; (n + 1) * stride];
                act.tower_into(&xs, n, &mut out, stride, Isa::Scalar);
                for (e, &x) in xs.iter().enumerate() {
                    let scalar = act.tower_scalar(x, n);
                    for (k, &want) in scalar.iter().enumerate() {
                        let got = out[k * stride + e];
                        assert!(
                            (got - want).abs() < 1e-12 * (1.0 + want.abs()),
                            "{} n={n} k={k} e={e}: {got} vs {want}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn generic_tensor_tower_fallback_matches() {
        let g = Gelu;
        let x = Tensor::linspace(-1.0, 1.0, 5);
        let towers = SmoothActivation::tower(&g, &x, 3);
        for (i, &xi) in x.data().iter().enumerate() {
            let sc = g.tower_scalar(xi, 3);
            for k in 0..=3 {
                assert_eq!(towers[k].data()[i], sc[k]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "tower order")]
    fn tower_bounds_checked() {
        Tanh::new(2).tower_scalar(0.0, 3);
    }
}
