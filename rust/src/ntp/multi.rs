//! Multivariate mixed partials from batched **directional** jets.
//!
//! The paper's n-TangentProp computes `d^n/dx^n f` for scalar inputs; real
//! PINN operators (`u_t − κ·u_xx`, `Δu`, biharmonic terms) need mixed
//! partials `∂^α u` over multi-dimensional inputs. Following the
//! reduction used by STDE (Shi et al., 2024) and DOF (Li et al., 2024),
//! every order-`m` mixed partial is a fixed linear combination of
//! order-`m` *directional* derivatives: for any direction `v`,
//!
//! ```text
//! D_v^m u = d^m/dt^m u(x + t·v) |_{t=0} = Σ_{|β| = m} (m!/β!) v^β ∂^β u
//! ```
//!
//! so evaluating `D_v^m u` over a direction set whose degree-`m` moment
//! matrix `M[k][β] = (m!/β!) v_k^β` is invertible recovers **every**
//! `∂^α u` with `|α| = m` exactly: `∂ = M⁻¹ D` (the polarization
//! identity, e.g. `u_xy = ½(D²_{(1,1)} − D²_{(1,0)} − D²_{(0,1)})` in
//! 2-D). Each `D_v^m` is one univariate n-TangentProp pass along the
//! curve `t ↦ x + t·v` — exactly the shape the fused
//! [`NtpEngine::forward_directional`] kernel is fast at — so an operator
//! over `D` directions costs `D · O(n log n)` fused passes instead of
//! exponential nested autodiff.
//!
//! [`JetPlan`] compiles the direction sets once per `(dim, n)`:
//! candidate integer directions (primitive, entries `0..=n`, smallest
//! first) are selected greedily under **exact rational** rank tracking,
//! and each order's moment matrix is inverted in rational arithmetic —
//! the recombination weights are exact before the final `f64`
//! conversion. Directions are shared across orders wherever possible, so
//! one direction-stacked batch (`[D·B, d]`) serves every order ≤ n.
//!
//! The supported range is generous for PDE work: across the whole
//! `dim ≤ 4`, `n ≤ 8` envelope the largest exact intermediate of the
//! solve stays below `2^68` (measured at the worst corner, the 165-row
//! order-8 system in 4-D), far inside `i128`'s `2^127`; every
//! multiplication is checked and panics loudly rather than overflowing
//! silently.

use super::forward::{NtpEngine, ParallelPolicy};
use crate::nn::Mlp;
use crate::tensor::Tensor;

// ------------------------------------------------------------ rationals

/// Checked-arithmetic unwrap for the exact solve.
fn ck(v: Option<i128>) -> i128 {
    v.expect("rational overflow solving the recombination system (dim or order too large)")
}

fn gcd_i128(mut a: i128, mut b: i128) -> i128 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// An exact rational with `i128` parts (always reduced, `den > 0`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Rat {
    num: i128,
    den: i128,
}

impl Rat {
    fn new(num: i128, den: i128) -> Rat {
        assert!(den != 0, "rational with zero denominator");
        let (num, den) = if den < 0 { (ck(num.checked_neg()), -den) } else { (num, den) };
        if num == 0 {
            return Rat { num: 0, den: 1 };
        }
        let g = gcd_i128(num.abs(), den);
        Rat { num: num / g, den: den / g }
    }

    fn int(n: i128) -> Rat {
        Rat { num: n, den: 1 }
    }

    fn is_zero(self) -> bool {
        self.num == 0
    }

    fn add(self, o: Rat) -> Rat {
        let num = ck(ck(self.num.checked_mul(o.den)).checked_add(ck(o.num.checked_mul(self.den))));
        Rat::new(num, ck(self.den.checked_mul(o.den)))
    }

    fn sub(self, o: Rat) -> Rat {
        self.add(Rat { num: ck(o.num.checked_neg()), den: o.den })
    }

    fn mul(self, o: Rat) -> Rat {
        Rat::new(ck(self.num.checked_mul(o.num)), ck(self.den.checked_mul(o.den)))
    }

    fn div(self, o: Rat) -> Rat {
        assert!(!o.is_zero(), "rational division by zero");
        Rat::new(ck(self.num.checked_mul(o.den)), ck(self.den.checked_mul(o.num)))
    }

    fn to_f64(self) -> f64 {
        self.num as f64 / self.den as f64
    }
}

/// Gauss-Jordan inversion over exact rationals. Returns `None` when the
/// matrix is singular (cannot happen for greedily rank-selected rows).
fn invert_rational(mut m: Vec<Vec<Rat>>) -> Option<Vec<Vec<Rat>>> {
    let nn = m.len();
    let mut inv: Vec<Vec<Rat>> = (0..nn)
        .map(|i| (0..nn).map(|j| Rat::int(i128::from(i == j))).collect())
        .collect();
    for col in 0..nn {
        let piv = (col..nn).find(|&r| !m[r][col].is_zero())?;
        m.swap(col, piv);
        inv.swap(col, piv);
        let p = m[col][col];
        for j in 0..nn {
            m[col][j] = m[col][j].div(p);
            inv[col][j] = inv[col][j].div(p);
        }
        for r in 0..nn {
            if r == col || m[r][col].is_zero() {
                continue;
            }
            let f = m[r][col];
            for j in 0..nn {
                let mj = f.mul(m[col][j]);
                m[r][j] = m[r][j].sub(mj);
                let ij = f.mul(inv[col][j]);
                inv[r][j] = inv[r][j].sub(ij);
            }
        }
    }
    Some(inv)
}

/// Incremental exact rank tracker: reduced rows + their pivot columns.
struct Echelon {
    rows: Vec<Vec<Rat>>,
    pivots: Vec<usize>,
}

impl Echelon {
    fn new() -> Echelon {
        Echelon { rows: Vec::new(), pivots: Vec::new() }
    }

    /// Reduce `row` against the current echelon; if independent, absorb
    /// it (normalized) and return `true`.
    fn try_add(&mut self, mut row: Vec<Rat>) -> bool {
        for (r, &p) in self.rows.iter().zip(&self.pivots) {
            if !row[p].is_zero() {
                let f = row[p];
                for (x, &e) in row.iter_mut().zip(r) {
                    *x = x.sub(f.mul(e));
                }
            }
        }
        match row.iter().position(|x| !x.is_zero()) {
            None => false,
            Some(p) => {
                let lead = row[p];
                for x in row.iter_mut() {
                    *x = x.div(lead);
                }
                self.rows.push(row);
                self.pivots.push(p);
                true
            }
        }
    }
}

// ---------------------------------------------------- multi-index tools

/// All multi-indices `α` with `|α| = m` over `dim` axes, in a fixed
/// lexicographic order (first axis most significant, descending) — the
/// column order of every recombination matrix.
pub fn multi_indices(dim: usize, m: usize) -> Vec<Vec<usize>> {
    fn rec(axis: usize, rem: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if axis + 1 == cur.len() {
            cur[axis] = rem;
            out.push(cur.clone());
            return;
        }
        for v in (0..=rem).rev() {
            cur[axis] = v;
            rec(axis + 1, rem - v, cur, out);
        }
    }
    assert!(dim >= 1, "multi_indices needs at least one axis");
    let mut out = Vec::new();
    let mut cur = vec![0usize; dim];
    rec(0, m, &mut cur, &mut out);
    out
}

/// Checked factorial (silent wrapping would corrupt the "exact" weights;
/// overflow means the requested order is far outside the envelope).
fn factorial_i128(n: usize) -> i128 {
    (1..=n as i128).fold(1i128, |acc, v| ck(acc.checked_mul(v)))
}

/// `|α|! / Πᵢ αᵢ!` — the moment-matrix coefficient of `∂^α`.
fn multinomial(alpha: &[usize]) -> i128 {
    let mut r = factorial_i128(alpha.iter().sum());
    for &a in alpha {
        r /= factorial_i128(a);
    }
    r
}

/// The degree-`m` moment row of direction `v`:
/// `row[β] = (m!/β!) · v^β` over `multis` (all `|β| = m`).
fn moment_row(v: &[i64], multis: &[Vec<usize>]) -> Vec<Rat> {
    multis
        .iter()
        .map(|alpha| {
            let mut val = multinomial(alpha);
            for (&vi, &ai) in v.iter().zip(alpha) {
                for _ in 0..ai {
                    val = ck(val.checked_mul(i128::from(vi)));
                }
            }
            Rat::int(val)
        })
        .collect()
}

/// Primitive candidate directions with entries `0..=max_entry`, sorted
/// smallest-first (entry sum, then lexicographic). Scalar multiples of a
/// direction scale its degree-`m` moment row by `c^m`, so primitive
/// vectors carry the full span; entries up to `m` suffice for rank (a
/// homogeneous degree-`m` polynomial vanishing on the `{0..m}^d` grid is
/// identically zero).
fn candidate_directions(dim: usize, max_entry: i64) -> Vec<Vec<i64>> {
    let base = max_entry as usize + 1;
    let total = base.pow(dim as u32);
    let mut out: Vec<Vec<i64>> = Vec::new();
    for idx in 0..total {
        let mut rem = idx;
        let mut v = vec![0i64; dim];
        for slot in v.iter_mut() {
            *slot = (rem % base) as i64;
            rem /= base;
        }
        if v.iter().all(|&c| c == 0) {
            continue;
        }
        let g = v.iter().fold(0i128, |acc, &c| gcd_i128(acc, i128::from(c)));
        if g != 1 {
            continue;
        }
        out.push(v);
    }
    out.sort_by_key(|v| (v.iter().sum::<i64>(), v.clone()));
    out
}

// --------------------------------------------------- RecombinationPlan

/// What a direction/recombination plan exposes to the partial-assembly
/// paths: a pool of integer directions and, per multi-index, the weight
/// row recombining directional jets into `∂^α u`.
///
/// Two implementations exist: the exact [`JetPlan`] (every `|α| ≤ n`
/// recombinable, direction count combinatorial in `dim`) and the
/// stochastic [`crate::ntp::stde::StdePlan`] (only the operator's own
/// factors recombinable, direction count bounded by the factor
/// supports) — the training tape builder is generic over the two.
pub trait RecombinationPlan {
    /// Number of input axes.
    fn dim(&self) -> usize;

    /// The union direction pool (integer vectors, one jet pass each).
    fn directions(&self) -> &[Vec<i64>];

    /// Recombination row for `∂^α`: `(dir_ids, weights)` with
    /// `∂^α u = Σ_k weights[k] · D_{directions()[dir_ids[k]]}^{|α|} u`.
    fn weights_for(&self, alpha: &[usize]) -> (&[usize], &[f64]);

    /// Number of directions in the pool.
    fn n_directions(&self) -> usize {
        self.directions().len()
    }
}

// -------------------------------------------------------------- JetPlan

/// Recombination weights for one derivative order: the selected
/// directions and the exact inverse moment matrix (as `f64`).
struct OrderPlan {
    /// All `|α| = m` multi-indices ([`multi_indices`] order).
    multis: Vec<Vec<usize>>,
    /// Indices into [`JetPlan::directions`], selection order.
    dir_ids: Vec<usize>,
    /// `weights[a][k]`: `∂^{multis[a]} u = Σ_k weights[a][k] · D_{v_k}^m u`.
    weights: Vec<Vec<f64>>,
}

/// A compiled direction set + exact recombination for every mixed
/// partial `∂^α u`, `1 ≤ |α| ≤ n`, over `dim` input axes.
///
/// Built once per `(dim, n)`: the per-order moment systems are solved in
/// exact rational arithmetic (see the module docs), directions are
/// shared across orders, and the result is plain data — cheap to clone
/// into shards and [`Send`]/[`Sync`] by construction.
pub struct JetPlan {
    dim: usize,
    n: usize,
    directions: Vec<Vec<i64>>,
    orders: Vec<OrderPlan>,
}

impl JetPlan {
    /// Compile direction sets and recombination weights for all orders
    /// `≤ n` over `dim` axes.
    ///
    /// Panics if the candidate grid fails to span some order (cannot
    /// happen for `dim ≥ 1` — a homogeneous degree-`m` polynomial cannot
    /// vanish on the whole `{0..m}^dim` grid) or if an exact
    /// intermediate would overflow `i128` (far outside the supported
    /// `dim ≤ 4`, `n ≤ 8` envelope).
    pub fn new(dim: usize, n: usize) -> JetPlan {
        assert!(dim >= 1, "JetPlan needs at least one input axis");
        let cands = candidate_directions(dim, n.max(1) as i64);
        let mut directions: Vec<Vec<i64>> = Vec::new();
        let mut orders = Vec::with_capacity(n);
        for m in 1..=n {
            let multis = multi_indices(dim, m);
            let want = multis.len();
            let mut ech = Echelon::new();
            let mut dir_ids: Vec<usize> = Vec::with_capacity(want);
            // Pass 1: reuse directions other orders already selected, so
            // the union batch stays small.
            for (id, v) in directions.iter().enumerate() {
                if dir_ids.len() == want {
                    break;
                }
                if ech.try_add(moment_row(v, &multis)) {
                    dir_ids.push(id);
                }
            }
            // Pass 2: fresh candidates, smallest first.
            for v in &cands {
                if dir_ids.len() == want {
                    break;
                }
                if directions.contains(v) {
                    continue;
                }
                if ech.try_add(moment_row(v, &multis)) {
                    directions.push(v.clone());
                    dir_ids.push(directions.len() - 1);
                }
            }
            assert_eq!(
                dir_ids.len(),
                want,
                "direction candidates failed to span order {m} over {dim} axes"
            );
            let mat: Vec<Vec<Rat>> = dir_ids
                .iter()
                .map(|&id| moment_row(&directions[id], &multis))
                .collect();
            let inv = invert_rational(mat).expect("rank-selected moment matrix is invertible");
            let weights = inv
                .iter()
                .map(|r| r.iter().map(|x| x.to_f64()).collect())
                .collect();
            orders.push(OrderPlan { multis, dir_ids, weights });
        }
        JetPlan { dim, n, directions, orders }
    }

    /// Number of input axes.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Highest recombinable derivative order.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The union direction set (integer vectors, one jet pass each).
    pub fn directions(&self) -> &[Vec<i64>] {
        &self.directions
    }

    /// Number of directions in the union set (`D` in the cost model
    /// `D · O(n log n)`).
    pub fn n_directions(&self) -> usize {
        self.directions.len()
    }

    /// All `|α| = m` multi-indices, in recombination-column order.
    pub fn multis(&self, m: usize) -> &[Vec<usize>] {
        assert!(m >= 1 && m <= self.n, "order {m} outside plan (n = {})", self.n);
        &self.orders[m - 1].multis
    }

    /// The direction ids (into [`JetPlan::directions`]) whose order-`m`
    /// jets recombine order-`m` partials.
    pub fn dir_ids(&self, m: usize) -> &[usize] {
        assert!(m >= 1 && m <= self.n, "order {m} outside plan (n = {})", self.n);
        &self.orders[m - 1].dir_ids
    }

    /// Recombination row for `∂^α`: `(dir_ids, weights)` with
    /// `∂^α u = Σ_k weights[k] · D_{directions[dir_ids[k]]}^{|α|} u`.
    pub fn weights_for(&self, alpha: &[usize]) -> (&[usize], &[f64]) {
        assert_eq!(alpha.len(), self.dim, "multi-index arity must match the plan dim");
        let m: usize = alpha.iter().sum();
        assert!(m >= 1 && m <= self.n, "order {m} outside plan (n = {})", self.n);
        let plan = &self.orders[m - 1];
        let a = plan
            .multis
            .iter()
            .position(|x| x.as_slice() == alpha)
            .expect("every |α| = m multi-index is tabulated");
        (&plan.dir_ids, &plan.weights[a])
    }
}

impl RecombinationPlan for JetPlan {
    fn dim(&self) -> usize {
        JetPlan::dim(self)
    }

    fn directions(&self) -> &[Vec<i64>] {
        JetPlan::directions(self)
    }

    fn weights_for(&self, alpha: &[usize]) -> (&[usize], &[f64]) {
        JetPlan::weights_for(self, alpha)
    }

    fn n_directions(&self) -> usize {
        JetPlan::n_directions(self)
    }
}

// ------------------------------------------------------- MultiJetEngine

/// Mixed-partial engine: a [`JetPlan`] driving the fused
/// [`NtpEngine::forward_directional`] kernel with **direction-stacked
/// batches** — all `D` directions of a `B`-point cloud run as one
/// `[D·B, d]` fused batch, then [`MultiJet::partial`] recombines jets
/// into exact mixed partials.
///
/// ```
/// use ntangent::nn::Mlp;
/// use ntangent::ntp::MultiJetEngine;
/// use ntangent::tensor::Tensor;
/// use ntangent::util::prng::Prng;
///
/// let mut rng = Prng::seeded(5);
/// let mlp = Mlp::uniform(2, 8, 2, 1, &mut rng); // u(x, y)
/// let x = Tensor::rand_uniform(&[32, 2], -1.0, 1.0, &mut rng);
/// let engine = MultiJetEngine::new(2, 2); // dim 2, orders ≤ 2
/// let jet = engine.jet(&mlp, &x);
/// let lap = jet.partial(&[2, 0]).add(&jet.partial(&[0, 2])); // Δu
/// assert_eq!(lap.shape(), &[32, 1]);
/// ```
pub struct MultiJetEngine {
    engine: NtpEngine,
    plan: JetPlan,
}

impl MultiJetEngine {
    /// Serial engine for `dim` input axes and derivative orders `≤ n`.
    pub fn new(dim: usize, n: usize) -> MultiJetEngine {
        MultiJetEngine::with_policy(dim, n, ParallelPolicy::Serial)
    }

    /// Engine with an explicit batch-parallelism policy (the stacked
    /// `[D·B, d]` batch row-chunks across threads bitwise-identically,
    /// like every other fused forward).
    pub fn with_policy(dim: usize, n: usize, policy: ParallelPolicy) -> MultiJetEngine {
        MultiJetEngine {
            engine: NtpEngine::with_policy(n, policy),
            plan: JetPlan::new(dim, n),
        }
    }

    /// The compiled direction/recombination plan.
    pub fn plan(&self) -> &JetPlan {
        &self.plan
    }

    /// The underlying univariate engine.
    pub fn engine(&self) -> &NtpEngine {
        &self.engine
    }

    /// Evaluate the full directional jet set at `x: [B, dim]` — one
    /// fused direction-stacked forward — ready for mixed-partial
    /// assembly.
    pub fn jet(&self, mlp: &Mlp, x: &Tensor) -> MultiJet<'_> {
        assert_eq!(x.rank(), 2, "x must be [B, dim]");
        assert_eq!(x.shape()[1], self.plan.dim(), "point dim must match the plan");
        assert_eq!(
            mlp.input_dim(),
            self.plan.dim(),
            "network input dim must match the plan"
        );
        let _span = crate::obs::span("ntp.multi.jet");
        let batch = x.shape()[0];
        let dim = self.plan.dim();
        let dirs = self.plan.directions();
        // n = 0 plans have no directions but the jet still carries u:
        // run one block along the zero direction.
        let blocks = dirs.len().max(1);
        let mut xs = Vec::with_capacity(blocks * batch * dim);
        let mut vs = Vec::with_capacity(blocks * batch * dim);
        if dirs.is_empty() {
            xs.extend_from_slice(x.data());
            vs.resize(batch * dim, 0.0);
        } else {
            for v in dirs {
                xs.extend_from_slice(x.data());
                for _ in 0..batch {
                    vs.extend(v.iter().map(|&c| c as f64));
                }
            }
        }
        let xs = Tensor::from_vec(xs, &[blocks * batch, dim]);
        let vs = Tensor::from_vec(vs, &[blocks * batch, dim]);
        let channels = self.engine.forward_directional(mlp, &xs, &vs, self.plan.n());
        MultiJet {
            plan: &self.plan,
            batch,
            out_dim: mlp.output_dim(),
            channels,
        }
    }
}

/// The directional jets of one collocation cloud: `channels[m]` holds
/// `D_v^m u` for every compiled direction, stacked `[D·B, out]` with
/// direction `k`'s block at rows `k·B..(k+1)·B`.
pub struct MultiJet<'a> {
    plan: &'a JetPlan,
    batch: usize,
    out_dim: usize,
    channels: Vec<Tensor>,
}

impl MultiJet<'_> {
    /// Rows of the underlying collocation cloud.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// `u(x)` itself — order 0 of any directional curve.
    pub fn value(&self) -> Tensor {
        let plane = self.batch * self.out_dim;
        Tensor::from_vec(
            self.channels[0].data()[..plane].to_vec(),
            &[self.batch, self.out_dim],
        )
    }

    /// The raw order-`m` jet block of direction `dir_id`.
    pub fn directional(&self, dir_id: usize, m: usize) -> &[f64] {
        let plane = self.batch * self.out_dim;
        &self.channels[m].data()[dir_id * plane..(dir_id + 1) * plane]
    }

    /// Assemble the exact mixed partial `∂^α u` as `[B, out]`.
    ///
    /// A fixed ascending-`k` weighted sum over the recombination row, so
    /// the result inherits the jets' bitwise thread-count invariance.
    pub fn partial(&self, alpha: &[usize]) -> Tensor {
        let m: usize = alpha.iter().sum();
        if m == 0 {
            return self.value();
        }
        let (dir_ids, w) = self.plan.weights_for(alpha);
        let plane = self.batch * self.out_dim;
        let mut out = vec![0.0; plane];
        for (&id, &wk) in dir_ids.iter().zip(w) {
            let src = self.directional(id, m);
            for (o, &s) in out.iter_mut().zip(src) {
                *o += wk * s;
            }
        }
        Tensor::from_vec(out, &[self.batch, self.out_dim])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn rational_arithmetic_reduces() {
        let a = Rat::new(2, 4);
        assert_eq!(a, Rat::new(1, 2));
        assert_eq!(a.add(a), Rat::int(1));
        assert_eq!(Rat::new(1, 3).mul(Rat::new(3, 5)), Rat::new(1, 5));
        assert_eq!(Rat::new(7, -2), Rat::new(-7, 2));
        assert_eq!(Rat::new(1, 2).sub(Rat::new(1, 2)), Rat::int(0));
        assert_eq!(Rat::new(1, 2).div(Rat::new(1, 4)), Rat::int(2));
        assert_eq!(Rat::new(1, 4).to_f64(), 0.25);
    }

    #[test]
    fn multi_index_counts_are_binomial() {
        // C(m + d - 1, d - 1) compositions of m into d parts.
        assert_eq!(multi_indices(1, 5).len(), 1);
        assert_eq!(multi_indices(2, 4).len(), 5);
        assert_eq!(multi_indices(3, 4).len(), 15);
        assert_eq!(multi_indices(4, 3).len(), 20);
        // Fixed lexicographic order, first axis descending.
        assert_eq!(multi_indices(2, 2), vec![vec![2, 0], vec![1, 1], vec![0, 2]]);
        // Every index sums to m, no duplicates.
        let ms = multi_indices(3, 4);
        for a in &ms {
            assert_eq!(a.iter().sum::<usize>(), 4);
        }
        for (i, a) in ms.iter().enumerate() {
            assert!(!ms[i + 1..].contains(a), "duplicate multi-index {a:?}");
        }
    }

    #[test]
    fn multinomial_values() {
        assert_eq!(multinomial(&[2, 0]), 1);
        assert_eq!(multinomial(&[1, 1]), 2);
        assert_eq!(multinomial(&[2, 2]), 6);
        assert_eq!(multinomial(&[1, 1, 1]), 6);
    }

    #[test]
    fn invert_rational_known_matrix() {
        // [[1, 2], [3, 4]]⁻¹ = [[-2, 1], [3/2, -1/2]]
        let m = vec![
            vec![Rat::int(1), Rat::int(2)],
            vec![Rat::int(3), Rat::int(4)],
        ];
        let inv = invert_rational(m).unwrap();
        assert_eq!(inv[0], vec![Rat::int(-2), Rat::int(1)]);
        assert_eq!(inv[1], vec![Rat::new(3, 2), Rat::new(-1, 2)]);
        // Singular matrices report None.
        let s = vec![
            vec![Rat::int(1), Rat::int(2)],
            vec![Rat::int(2), Rat::int(4)],
        ];
        assert!(invert_rational(s).is_none());
    }

    /// The defining identity of the recombination: for every order `m`,
    /// `Σ_k weights[α][k] · (m!/β!) v_k^β = δ_{αβ}` — i.e. assembling
    /// "partials" from the exact directional derivatives of any
    /// degree-`m` monomial reproduces exactly that monomial's partials.
    #[test]
    fn recombination_weights_invert_the_moment_matrix() {
        for (dim, n) in [(1usize, 4usize), (2, 4), (3, 3), (2, 6)] {
            let plan = JetPlan::new(dim, n);
            for m in 1..=n {
                let multis = plan.multis(m).to_vec();
                let ids = plan.dir_ids(m).to_vec();
                for (a, alpha) in multis.iter().enumerate() {
                    let (dir_ids, w) = plan.weights_for(alpha);
                    assert_eq!(dir_ids, &ids[..]);
                    for (b, beta) in multis.iter().enumerate() {
                        let mut acc = 0.0;
                        for (&id, &wk) in dir_ids.iter().zip(w) {
                            let mut mom = multinomial(beta) as f64;
                            for (&vi, &bi) in plan.directions()[id].iter().zip(beta) {
                                mom *= (vi as f64).powi(bi as i32);
                            }
                            acc += wk * mom;
                        }
                        let want = if a == b { 1.0 } else { 0.0 };
                        assert!(
                            (acc - want).abs() < 1e-9,
                            "dim={dim} m={m} α={alpha:?} β={beta:?}: {acc}"
                        );
                    }
                }
            }
        }
    }

    /// 2-D order-2 must reproduce the textbook polarization identity:
    /// `u_xy = ½·D²_{(1,1)} − ½·D²_{(1,0)} − ½·D²_{(0,1)}`.
    #[test]
    fn plan_2d_order2_is_the_polarization_identity() {
        let plan = JetPlan::new(2, 2);
        assert_eq!(plan.directions(), &[vec![0, 1], vec![1, 0], vec![1, 1]]);
        let (ids, w) = plan.weights_for(&[1, 1]);
        let mut by_dir = vec![0.0; plan.n_directions()];
        for (&id, &wk) in ids.iter().zip(w) {
            by_dir[id] = wk;
        }
        assert_eq!(by_dir, vec![-0.5, -0.5, 0.5]);
    }

    /// The documented envelope's worst corner actually builds: the
    /// 4-D, order-8 plan (165 directions, 165×165 exact solve; the
    /// largest intermediate measures ~2^68, inside `i128`).
    #[test]
    fn envelope_corner_plan_builds() {
        let plan = JetPlan::new(4, 8);
        assert_eq!(plan.multis(8).len(), 165); // C(8+3, 3)
        assert_eq!(plan.dir_ids(8).len(), 165);
        assert!(plan.n_directions() >= 165);
    }

    #[test]
    fn directions_are_shared_across_orders() {
        // dim 2, n 2: orders 1 and 2 need 2 + 3 rows but the union is 3
        // directions (the unit vectors serve both orders).
        let plan = JetPlan::new(2, 2);
        assert_eq!(plan.n_directions(), 3);
        // dim 3, n 4: ≤ 15 directions serve all 3 + 6 + 10 + 15 rows.
        let plan = JetPlan::new(3, 4);
        assert_eq!(plan.n_directions(), 15);
    }

    /// First-order partials recombine with an exact 0/1 weight row (the
    /// unit vectors are always selected), so `∂u/∂xᵢ` equals the raw
    /// `e_i` jet block bit for bit.
    #[test]
    fn first_order_partials_equal_unit_direction_jets() {
        let mut rng = Prng::seeded(7);
        let mlp = Mlp::uniform(2, 8, 2, 1, &mut rng);
        let x = Tensor::rand_uniform(&[10, 2], -1.0, 1.0, &mut rng);
        let engine = MultiJetEngine::new(2, 2);
        let jet = engine.jet(&mlp, &x);
        for (axis, alpha) in [[1usize, 0], [0, 1]].iter().enumerate() {
            let got = jet.partial(alpha);
            let unit: Vec<i64> = (0..2).map(|i| i64::from(i == axis)).collect();
            let dir_id = engine
                .plan()
                .directions()
                .iter()
                .position(|v| v == &unit)
                .unwrap();
            assert_eq!(got.data(), jet.directional(dir_id, 1), "axis {axis}");
        }
    }

    /// Jets and assembled partials are bitwise invariant under the
    /// engine's batch-parallel policy.
    #[test]
    fn jet_partials_are_policy_invariant_bitwise() {
        let mut rng = Prng::seeded(8);
        let mlp = Mlp::uniform(2, 10, 2, 1, &mut rng);
        let x = Tensor::rand_uniform(&[13, 2], -1.0, 1.0, &mut rng);
        let serial = MultiJetEngine::new(2, 3);
        let par = MultiJetEngine::with_policy(2, 3, ParallelPolicy::Fixed(3));
        let js = serial.jet(&mlp, &x);
        let jp = par.jet(&mlp, &x);
        for alpha in [[0usize, 0], [1, 0], [2, 0], [1, 1], [0, 3], [2, 1]] {
            assert_eq!(js.partial(&alpha), jp.partial(&alpha), "α = {alpha:?}");
        }
    }

    #[test]
    #[should_panic(expected = "outside plan")]
    fn partial_order_above_plan_panics() {
        let mut rng = Prng::seeded(9);
        let mlp = Mlp::uniform(2, 4, 1, 1, &mut rng);
        let x = Tensor::zeros(&[2, 2]);
        let engine = MultiJetEngine::new(2, 1);
        engine.jet(&mlp, &x).partial(&[2, 0]);
    }
}
