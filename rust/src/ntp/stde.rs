//! Stochastic Taylor derivative estimation (STDE): unbiased
//! high-dimensional operator estimates from **sparse random direction
//! sets**, following Shi et al. (arxiv 2412.00088) and DOF (arxiv
//! 2402.09730).
//!
//! The exact [`JetPlan`] recombines *every* `|α| ≤ n` partial, so its
//! direction count grows like `C(d+n−1, d−1)` — 55 directions for a
//! 10-D Laplacian, 5050 for a 100-D one. But a PDE residual never needs
//! every partial: it needs the operator's *own* factors. STDE therefore
//! subsamples the operator's term list each step and evaluates only the
//! sampled factors, each **exactly**, from a handful of directions
//! supported on that factor's axes:
//!
//! 1. [`StdePlan::new`] analyses the operator's
//!    [`crate::pde::DiffOperator::sparsity`]: each factor `∂^α` with
//!    axis support `S` gets a mini moment system over `|S|` axes (a
//!    [`JetPlan`] on the support, solved in exact rational arithmetic),
//!    whose directions embed sparsely into `ℝ^d`. A pure-axis factor
//!    like `∂²/∂x_i²` costs exactly one direction `e_i`; a 2-axis mixed
//!    factor costs the 3-direction polarization set.
//! 2. [`sample_terms`] draws `K` term indices per `(step, shard)` from
//!    the counter-based [`CounterRng`] — every draw is a pure function
//!    of `(seed, step, shard, index)`, so the sample is bitwise
//!    identical for any thread count or evaluation order.
//! 3. [`sampled_operator`] turns the draws into a small
//!    Horvitz–Thompson reweighted operator: term `t` sampled `μ_t`
//!    times contributes `μ_t·(T/K)·c_t·Π_f ∂^{α_f} u`, an **unbiased**
//!    estimator of `L[u]` (each factor is exact, only the term
//!    selection is random — products need no independence correction).
//! 4. The sampled directions run as one direction-stacked fused batch
//!    (`[D·B, d]`, the [`MultiJetEngine`]-style launch) through
//!    [`NtpEngine::forward_directional`].
//!
//! Variance is controlled three ways: the sample count `K` (variance
//! decays ~1/K), optional **antithetic pairing** (paired draws select
//! index-reflected terms, anticorrelating the picked coefficients), and
//! the operator-adapted sparsity above (a pure-axis operator like
//! 100-D heat never pays for mixed-partial direction sets). The exact
//! path remains the differential oracle at low `d`; the statistical
//! contract lives in `rust/tests/stde_statistics.rs` and the
//! determinism contract in `rust/tests/stde_determinism.rs`.
//!
//! [`MultiJetEngine`]: crate::ntp::MultiJetEngine

use super::forward::{NtpEngine, ParallelPolicy};
use super::multi::{JetPlan, RecombinationPlan};
use crate::nn::Mlp;
use crate::pde::DiffOperator;
use crate::tensor::Tensor;
use std::collections::HashMap;

// ---------------------------------------------------------- counter RNG

/// SplitMix64 finalizer: a full-avalanche 64-bit mix.
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A splittable **counter-based** generator: every output is a pure
/// function of `(seed, step, shard, index)` — no mutable stream state —
/// so parallel consumers can draw their own coordinates in any order
/// and still agree bitwise with a serial run. The stream is pinned by
/// committed golden draws in `rust/tests/stde_determinism.rs`; changing
/// the mixing chain is a breaking change to every seeded STDE run.
#[derive(Clone, Copy, Debug)]
pub struct CounterRng {
    seed: u64,
}

impl CounterRng {
    /// A generator for one 64-bit seed.
    pub fn new(seed: u64) -> CounterRng {
        CounterRng { seed }
    }

    /// The seed this generator was built with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    fn draw_at(seed: u64, step: u64, shard: u64, index: u64, attempt: u64) -> u64 {
        // Chain one avalanche round per coordinate (Weyl-offset seed
        // first), so neighbouring tuples decorrelate fully.
        let mut h = mix(seed ^ 0x9E3779B97F4A7C15);
        h = mix(h ^ step);
        h = mix(h ^ shard);
        h = mix(h ^ index);
        mix(h ^ attempt)
    }

    /// The raw 64-bit draw at a counter coordinate.
    pub fn draw(&self, step: u64, shard: u64, index: u64) -> u64 {
        CounterRng::draw_at(self.seed, step, shard, index, 0)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&self, step: u64, shard: u64, index: u64) -> f64 {
        (self.draw(step, shard, index) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exact uniform integer in `[0, n)` — zone rejection over an
    /// attempt counter folded into the same coordinate (still a pure
    /// function of the tuple, still platform-independent).
    pub fn below(&self, step: u64, shard: u64, index: u64, n: u64) -> u64 {
        assert!(n > 0, "CounterRng::below(0)");
        // Accept x < 2^64 − (2^64 mod n), i.e. x ≤ u64::MAX − rem.
        let rem = (u64::MAX % n + 1) % n;
        let limit = u64::MAX - rem;
        let mut attempt = 0u64;
        loop {
            let x = CounterRng::draw_at(self.seed, step, shard, index, attempt);
            if x <= limit {
                return x % n;
            }
            attempt += 1;
        }
    }
}

// ------------------------------------------------------- configuration

/// Knobs of one stochastic estimation stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StdeConfig {
    /// Seed of the counter-based stream.
    pub seed: u64,
    /// Term samples per `(step, shard)` — variance decays ~1/K.
    pub samples: usize,
    /// Pair draws antithetically (index-reflected term selection);
    /// requires an even sample count.
    pub antithetic: bool,
}

/// How a PDE objective evaluates its operator residual.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EstimatorMode {
    /// The exact [`JetPlan`] path — every partial recombined, direction
    /// count combinatorial in `dim` (the low-`d` oracle).
    Exact,
    /// Stochastic Taylor derivative estimation (this module): term
    /// subsampling with exact per-factor recombination.
    Stde {
        /// Seed of the counter-based stream.
        seed: u64,
        /// Term samples per `(step, shard)`.
        samples: usize,
        /// Antithetic pairing (even sample count required).
        antithetic: bool,
    },
}

impl EstimatorMode {
    /// The [`StdeConfig`] of a stochastic mode (`None` when exact).
    pub fn stde_config(&self) -> Option<StdeConfig> {
        match *self {
            EstimatorMode::Exact => None,
            EstimatorMode::Stde { seed, samples, antithetic } => {
                Some(StdeConfig { seed, samples, antithetic })
            }
        }
    }
}

// ------------------------------------------------------------- StdePlan

/// The compiled sparse direction pool of one operator: for every factor
/// `∂^α` the operator can ask for, an **exact** recombination row over
/// directions supported on `α`'s axes (mini rational moment systems on
/// the support — see the module docs). Plain data, `Send + Sync`.
pub struct StdePlan {
    dim: usize,
    directions: Vec<Vec<i64>>,
    /// `(α, dir_ids, weights)` per distinct operator factor with
    /// `|α| ≥ 1`, in [`DiffOperator::needed_partials`] order.
    rows: Vec<(Vec<usize>, Vec<usize>, Vec<f64>)>,
    max_order: usize,
}

impl StdePlan {
    /// Compile the factor-wise direction pool of `op`.
    ///
    /// Panics if a single factor couples more than 4 axes (the exact
    /// mini moment systems inherit the [`JetPlan`] support envelope) —
    /// the operator *dimension* is unbounded, only per-factor coupling
    /// is limited.
    pub fn new(op: &DiffOperator) -> StdePlan {
        let dim = op.dim();
        let sp = op.sparsity();
        assert!(
            sp.max_support <= 4,
            "a factor couples {} axes; exact per-factor moment systems support at most 4",
            sp.max_support
        );
        let mut directions: Vec<Vec<i64>> = Vec::new();
        let mut rows = Vec::new();
        let mut minis: HashMap<(Vec<usize>, usize), JetPlan> = HashMap::new();
        for alpha in op.needed_partials() {
            let m: usize = alpha.iter().sum();
            if m == 0 {
                continue;
            }
            let support: Vec<usize> = (0..dim).filter(|&i| alpha[i] > 0).collect();
            let local_alpha: Vec<usize> = support.iter().map(|&i| alpha[i]).collect();
            let mini = minis
                .entry((support.clone(), m))
                .or_insert_with(|| JetPlan::new(support.len(), m));
            let (local_ids, w) = mini.weights_for(&local_alpha);
            let mut dir_ids = Vec::with_capacity(local_ids.len());
            for &lid in local_ids {
                let local = &JetPlan::directions(mini)[lid];
                let mut v = vec![0i64; dim];
                for (slot, &axis) in support.iter().enumerate() {
                    v[axis] = local[slot];
                }
                let gid = match directions.iter().position(|d| d == &v) {
                    Some(g) => g,
                    None => {
                        directions.push(v);
                        directions.len() - 1
                    }
                };
                dir_ids.push(gid);
            }
            rows.push((alpha, dir_ids, w.to_vec()));
        }
        let max_order = op.max_order();
        StdePlan { dim, directions, rows, max_order }
    }

    /// Highest derivative order any factor requests.
    pub fn max_order(&self) -> usize {
        self.max_order
    }

    /// The tabulated factors, in [`DiffOperator::needed_partials`]
    /// order (order-0 factors are served by the jet value directly and
    /// carry no row).
    pub fn factors(&self) -> impl Iterator<Item = &[usize]> {
        self.rows.iter().map(|(a, _, _)| a.as_slice())
    }
}

impl RecombinationPlan for StdePlan {
    fn dim(&self) -> usize {
        self.dim
    }

    fn directions(&self) -> &[Vec<i64>] {
        &self.directions
    }

    fn weights_for(&self, alpha: &[usize]) -> (&[usize], &[f64]) {
        assert_eq!(alpha.len(), self.dim, "multi-index arity must match the plan dim");
        let row = self
            .rows
            .iter()
            .find(|(a, _, _)| a.as_slice() == alpha)
            .unwrap_or_else(|| {
                panic!("∂^{alpha:?} is not a factor of the planned operator")
            });
        (&row.1, &row.2)
    }
}

// ------------------------------------------------------------- sampling

/// Draw `cfg.samples` term indices (into a `n_terms`-long term list)
/// for one `(step, shard)` coordinate. Plain draws are exact-uniform;
/// antithetic mode reflects each pair's index (`j` and `T−1−j`, both
/// marginally uniform, perfectly anticorrelated), which cuts variance
/// whenever term magnitudes vary monotonically along the term list.
pub fn sample_terms(cfg: &StdeConfig, n_terms: usize, step: u64, shard: u64) -> Vec<usize> {
    assert!(n_terms >= 1, "sampling needs at least one term");
    assert!(cfg.samples >= 1, "sampling needs at least one draw");
    let rng = CounterRng::new(cfg.seed);
    let t = n_terms as u64;
    if cfg.antithetic {
        assert!(
            cfg.samples % 2 == 0,
            "antithetic pairing needs an even sample count (got {})",
            cfg.samples
        );
        (0..cfg.samples)
            .map(|k| {
                let j = rng.below(step, shard, (k / 2) as u64, t);
                (if k % 2 == 0 { j } else { t - 1 - j }) as usize
            })
            .collect()
    } else {
        (0..cfg.samples)
            .map(|k| rng.below(step, shard, k as u64, t) as usize)
            .collect()
    }
}

/// The Horvitz–Thompson reweighted operator of one draw: term `t`
/// sampled `μ_t` times keeps its factors with coefficient
/// `μ_t·(T/K)·c_t` (distinct terms in ascending id order, so downstream
/// accumulation order is a pure function of the draw). Its expectation
/// over draws is the full operator — the unbiasedness workhorse.
pub fn sampled_operator(op: &DiffOperator, samples: &[usize]) -> DiffOperator {
    assert!(!samples.is_empty(), "sampled_operator needs at least one draw");
    let t = op.terms().len();
    let mut mult = vec![0usize; t];
    for &s in samples {
        assert!(s < t, "sample {s} outside the {t}-term operator");
        mult[s] += 1;
    }
    let scale = t as f64 / samples.len() as f64;
    let mut out = DiffOperator::new(op.dim());
    for (id, term) in op.terms().iter().enumerate() {
        if mult[id] == 0 {
            continue;
        }
        out = out.with_product(term.coeff * scale * mult[id] as f64, term.factors.clone());
    }
    out
}

/// Direction count of the exact `|α| ≤ n` plan over `dim` axes:
/// `C(dim+n−1, dim−1)` (the order-`n` moment rows; lower orders share
/// directions) — the denominator of the bench's pass-ratio metric,
/// computable without building the combinatorial plan.
pub fn exact_direction_count(dim: usize, n: usize) -> u128 {
    if n == 0 {
        return 0;
    }
    // C(dim + n − 1, n), multiplicative form.
    let mut num: u128 = 1;
    for i in 0..n {
        num = num
            .checked_mul((dim + n - 1 - i) as u128)
            .expect("direction count overflows u128")
            / (i as u128 + 1);
    }
    num
}

// ----------------------------------------------------------- StdeEngine

/// One evaluated estimate: the values and the cost that produced them.
pub struct StdeEstimate {
    /// `L[u](x)` estimate, `[B, out]`.
    pub values: Tensor,
    /// Directional passes this step actually launched (the numerator of
    /// the bench's pass-ratio metric).
    pub n_directions: usize,
}

/// The inference-side estimator: a [`StdePlan`] driving the fused
/// directional kernel with per-step sampled sparse direction stacks.
///
/// ```
/// use ntangent::nn::Mlp;
/// use ntangent::ntp::stde::{StdeConfig, StdeEngine};
/// use ntangent::pde::PdeProblem;
/// use ntangent::util::prng::Prng;
///
/// let problem = PdeProblem::Poisson10d;
/// let mut rng = Prng::seeded(4);
/// let mlp = Mlp::uniform(10, 8, 2, 1, &mut rng);
/// let x = problem.sample_interior(16, &mut rng);
/// let cfg = StdeConfig { seed: 7, samples: 4, antithetic: false };
/// let est = StdeEngine::new(problem.operator(), cfg);
/// let e = est.estimate(&mlp, &x, 0);
/// assert_eq!(e.values.shape(), &[16, 1]);
/// // 4 samples of a pure-axis operator cost at most 4 directions —
/// // the exact 10-D plan would need 55.
/// assert!(e.n_directions <= 4);
/// ```
pub struct StdeEngine {
    op: DiffOperator,
    plan: StdePlan,
    cfg: StdeConfig,
    engine: NtpEngine,
}

impl StdeEngine {
    /// Serial estimator for `op` under `cfg`.
    pub fn new(op: DiffOperator, cfg: StdeConfig) -> StdeEngine {
        StdeEngine::with_policy(op, cfg, ParallelPolicy::Serial)
    }

    /// Estimator with an explicit batch-parallel policy (scheduling
    /// only — estimates are bitwise policy-invariant like every fused
    /// forward).
    pub fn with_policy(op: DiffOperator, cfg: StdeConfig, policy: ParallelPolicy) -> StdeEngine {
        let plan = StdePlan::new(&op);
        let n = op.max_order().max(1);
        StdeEngine {
            engine: NtpEngine::with_policy(n, policy),
            op,
            plan,
            cfg,
        }
    }

    /// The operator being estimated.
    pub fn operator(&self) -> &DiffOperator {
        &self.op
    }

    /// The compiled sparse direction pool.
    pub fn plan(&self) -> &StdePlan {
        &self.plan
    }

    /// The estimation config.
    pub fn config(&self) -> &StdeConfig {
        &self.cfg
    }

    /// Unbiased estimate of `L[u](x)` at counter step `step` over
    /// `x: [B, dim]` — sample terms, launch one `[D·B, d]`
    /// direction-stacked fused batch over the union of the sampled
    /// factors' directions, recombine each factor exactly, assemble the
    /// Horvitz–Thompson sum. Bitwise deterministic in `(seed, step)`.
    pub fn estimate(&self, mlp: &Mlp, x: &Tensor, step: u64) -> StdeEstimate {
        assert_eq!(x.rank(), 2, "x must be [B, dim]");
        assert_eq!(x.shape()[1], self.plan.dim, "point dim must match the plan");
        assert_eq!(mlp.input_dim(), self.plan.dim, "network input dim must match the plan");
        let _span = crate::obs::span("ntp.stde.estimate");
        let samples = sample_terms(&self.cfg, self.op.terms().len(), step, 0);
        let sop = sampled_operator(&self.op, &samples);
        self.apply_sampled(mlp, x, &sop)
    }

    /// Evaluate an already-sampled (reweighted) operator — the shared
    /// back half of [`StdeEngine::estimate`], also used by the bench's
    /// variance probes.
    pub fn apply_sampled(&self, mlp: &Mlp, x: &Tensor, sop: &DiffOperator) -> StdeEstimate {
        let batch = x.shape()[0];
        let dim = self.plan.dim;
        let out_dim = mlp.output_dim();
        let plane = batch * out_dim;

        // Which pool directions this draw needs, and to what order
        // (order-0 factors ride on channel 0 of any launched block).
        let mut need_order = vec![0usize; self.plan.directions.len()];
        for alpha in sop.needed_partials() {
            let m: usize = alpha.iter().sum();
            if m == 0 {
                continue;
            }
            let (ids, _) = self.plan.weights_for(&alpha);
            for &id in ids {
                need_order[id] = need_order[id].max(m);
            }
        }
        // Launch slots in ascending pool id — a pure function of the
        // draw, independent of term iteration order.
        let launched: Vec<usize> = (0..need_order.len())
            .filter(|&id| need_order[id] > 0)
            .collect();
        let n_launch = need_order.iter().copied().max().unwrap_or(0);
        let mut slot_of = vec![usize::MAX; self.plan.directions.len()];
        for (slot, &id) in launched.iter().enumerate() {
            slot_of[id] = slot;
        }

        // One stacked fused batch over the launched directions (or a
        // single zero-direction block when the draw is derivative-free).
        let blocks = launched.len().max(1);
        let mut xs = Vec::with_capacity(blocks * batch * dim);
        let mut vs = Vec::with_capacity(blocks * batch * dim);
        if launched.is_empty() {
            xs.extend_from_slice(x.data());
            vs.resize(batch * dim, 0.0);
        } else {
            for &id in &launched {
                xs.extend_from_slice(x.data());
                let dir = &self.plan.directions[id];
                for _ in 0..batch {
                    vs.extend(dir.iter().map(|&c| c as f64));
                }
            }
        }
        let xs = Tensor::from_vec(xs, &[blocks * batch, dim]);
        let vs = Tensor::from_vec(vs, &[blocks * batch, dim]);
        let channels = self.engine.forward_directional(mlp, &xs, &vs, n_launch);

        // Exact per-factor recombination: ∂^α = Σ_k w_k · channel_m[slot_k].
        let partial = |alpha: &[usize]| -> Vec<f64> {
            let m: usize = alpha.iter().sum();
            if m == 0 {
                return channels[0].data()[..plane].to_vec();
            }
            let (ids, w) = self.plan.weights_for(alpha);
            let mut out = vec![0.0; plane];
            for (&id, &wk) in ids.iter().zip(w) {
                let slot = slot_of[id];
                let src = &channels[m].data()[slot * plane..(slot + 1) * plane];
                for (o, &s) in out.iter_mut().zip(src) {
                    *o += wk * s;
                }
            }
            out
        };

        // Horvitz–Thompson assembly in (ascending) term order.
        let mut acc = vec![0.0; plane];
        for term in sop.terms() {
            let mut prod: Option<Vec<f64>> = None;
            for f in &term.factors {
                let p = partial(f);
                prod = Some(match prod {
                    None => p,
                    Some(mut q) => {
                        for (a, b) in q.iter_mut().zip(&p) {
                            *a *= b;
                        }
                        q
                    }
                });
            }
            let p = prod.expect("term has at least one factor");
            for (a, &b) in acc.iter_mut().zip(&p) {
                *a += term.coeff * b;
            }
        }
        StdeEstimate {
            values: Tensor::from_vec(acc, &[batch, out_dim]),
            n_directions: launched.len(),
        }
    }
}
