//! n-TangentProp recorded on the autodiff tape — the *training* path.
//!
//! For PINN training we need `∂L/∂θ` where the loss `L` depends on the
//! derivative channels `u^(i)`. The paper implements n-TangentProp as a
//! custom PyTorch `forward` and lets the standard backward run over it;
//! we do the same: record the channel propagation as tape ops (the
//! activation tower as generic `Act` nodes, then partition products), so
//! a *single* `backward` yields parameter gradients at tape-size cost
//! `O(n·p(n)·M)` — no repeated differentiation anywhere.
//!
//! The tower is recorded generically: `σ^(s)(y0)` is one `Act` node per
//! order, whose VJP is the next tower order. That keeps
//! backprop-through-derivatives exact for *every* registered
//! [`crate::ntp::ActivationKind`], not just tanh. Known tradeoff: each
//! `Act` node evaluates its own transcendental sweep, so one layer's
//! tower costs `n+1` such sweeps where the old tanh-only tape shared
//! one (and expanded polynomials in it); a shared-substitution tower op
//! could reclaim that if the tape eval ever dominates training.
//!
//! Recorded tapes are plain data (`Send + Sync`; the `Act` evaluator's
//! polynomial tables are memoized per thread), so the data-parallel
//! trainer records one such tape per collocation shard and evaluates
//! them concurrently — with bitwise-identical results, since every
//! thread runs the same recurrences.

use super::forward::NtpEngine;
use crate::autodiff::{Graph, NodeId};
use crate::nn::Mlp;

impl NtpEngine {
    /// Record `[u, u', ..., u^(n)]` on `g`, using `mlp.activation`'s
    /// derivative tower.
    ///
    /// `param_nodes` is the `W0, b0, W1, b1, ...` node list (constants for
    /// inference benchmarks, inputs for training — see
    /// [`Mlp::const_param_nodes`] / [`Mlp::input_param_nodes`]).
    pub fn forward_graph(
        &self,
        g: &mut Graph,
        mlp: &Mlp,
        x: NodeId,
        param_nodes: &[NodeId],
        n: usize,
    ) -> Vec<NodeId> {
        assert!(n <= self.n_max(), "n={n} exceeds engine n_max={}", self.n_max());
        assert_eq!(g.shape(x)[1], 1, "x must be [B, 1]");
        assert_eq!(param_nodes.len(), 2 * mlp.layers.len());
        let batch = g.shape(x)[0];

        // Seed channels from the first affine layer.
        let w0 = param_nodes[0];
        let b0 = param_nodes[1];
        let mut y: Vec<NodeId> = Vec::with_capacity(n + 1);
        let lin0 = g.matmul_nt(x, w0);
        y.push(g.add_bias(lin0, b0));
        if n >= 1 {
            let ones = g.constant(crate::tensor::Tensor::ones(&[batch, 1]));
            y.push(g.matmul_nt(ones, w0));
        }
        for _ in 2..=n {
            let z = g.zeros_like(y[0]);
            y.push(z);
        }

        self.propagate_graph(g, mlp, param_nodes, &mut y, n);
        y
    }

    /// Record the **directional** jet `[u, D_v u, ..., D_v^n u]` along
    /// per-row directions on `g`, for a multi-input network
    /// (`x: [B, d]`, `v: [B, d]` — typically a constant node).
    ///
    /// Training-path twin of [`NtpEngine::forward_directional`]: the
    /// curve `t ↦ f(x + t·v)` is a scalar restriction, so the recorded
    /// channel algebra is identical to [`NtpEngine::forward_graph`] —
    /// only the seeding changes (`y1 = v W0^T`, the chain rule through
    /// the first affine layer). The multivariate PINN objective
    /// ([`crate::pinn::MultiObjective`]) records one such pass per
    /// compiled direction and recombines the order-`m` channels into
    /// exact mixed-partial nodes.
    pub fn forward_graph_directional(
        &self,
        g: &mut Graph,
        mlp: &Mlp,
        x: NodeId,
        v: NodeId,
        param_nodes: &[NodeId],
        n: usize,
    ) -> Vec<NodeId> {
        assert!(n <= self.n_max(), "n={n} exceeds engine n_max={}", self.n_max());
        assert_eq!(
            g.shape(x)[1],
            mlp.input_dim(),
            "x dim must match the network input dim"
        );
        assert_eq!(g.shape(v), g.shape(x), "one direction row per point row");
        assert_eq!(param_nodes.len(), 2 * mlp.layers.len());

        let w0 = param_nodes[0];
        let b0 = param_nodes[1];
        let mut y: Vec<NodeId> = Vec::with_capacity(n + 1);
        let lin0 = g.matmul_nt(x, w0);
        y.push(g.add_bias(lin0, b0));
        if n >= 1 {
            y.push(g.matmul_nt(v, w0));
        }
        for _ in 2..=n {
            let z = g.zeros_like(y[0]);
            y.push(z);
        }
        self.propagate_graph(g, mlp, param_nodes, &mut y, n);
        y
    }

    /// Advance seeded channel nodes through the hidden/output layers
    /// (towers, shared power nodes, Faà di Bruno combine, affine) — the
    /// shared middle of [`NtpEngine::forward_graph`] and
    /// [`NtpEngine::forward_graph_directional`].
    fn propagate_graph(
        &self,
        g: &mut Graph,
        mlp: &Mlp,
        param_nodes: &[NodeId],
        y: &mut [NodeId],
        n: usize,
    ) {
        let kind = mlp.activation;
        for li in 1..mlp.layers.len() {
            let w = param_nodes[2 * li];
            let b = param_nodes[2 * li + 1];

            // σ^(s)(y0), s = 0..=n: one generic activation node per order.
            let towers: Vec<NodeId> = (0..=n).map(|s| g.act(y[0], kind, s)).collect();

            // §Perf: share the channel-power nodes y_j^c across all the
            // partition terms of this layer (mirrors the pure-forward
            // powers cache; shrinks both tape size and backward work).
            let powers = self.channel_power_nodes(g, y, n);
            for i in (1..=n).rev() {
                y[i] = self.combine_channel_nodes(g, i, &towers, &powers);
            }
            let lin = g.matmul_nt(towers[0], w);
            let h0 = g.add_bias(lin, b);
            for item in y.iter_mut().skip(1) {
                *item = g.matmul_nt(*item, w);
            }
            y[0] = h0;
        }
    }

    /// `powers[j][c-1] = y_j^c` as shared tape nodes (c ≤ n/j).
    fn channel_power_nodes(&self, g: &mut Graph, y: &[NodeId], n: usize) -> Vec<Vec<NodeId>> {
        let mut powers: Vec<Vec<NodeId>> = Vec::with_capacity(y.len());
        powers.push(Vec::new()); // j = 0 unused
        for (j, &yj) in y.iter().enumerate().skip(1) {
            let c_max = if j <= n { n / j } else { 0 };
            let mut row = Vec::with_capacity(c_max);
            if c_max >= 1 {
                row.push(yj);
                for _ in 2..=c_max {
                    let prev = *row.last().unwrap();
                    row.push(g.mul(prev, yj));
                }
            }
            powers.push(row);
        }
        powers
    }

    /// ξ_i = Σ_p C_p σ^{(|p|)} Π_j y_j^{p_j} as tape nodes.
    fn combine_channel_nodes(
        &self,
        g: &mut Graph,
        i: usize,
        towers: &[NodeId],
        powers: &[Vec<NodeId>],
    ) -> NodeId {
        let mut acc: Option<NodeId> = None;
        for term in self.tables().terms(i) {
            let mut prod = g.scale(towers[term.outer_order], term.coeff);
            for &(j, c) in &term.factors {
                prod = g.mul(prod, powers[j][c - 1]);
            }
            acc = Some(match acc {
                None => prod,
                Some(a) => g.add(a, prod),
            });
        }
        acc.expect("order >= 1 always has partitions")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::params;
    use crate::ntp::ActivationKind;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;
    use crate::util::{allclose_slice, ptest};

    #[test]
    fn tape_forward_matches_pure_forward() {
        ptest::check(
            ptest::Config { cases: 16, seed: 0xF00D },
            |rng: &mut Prng| {
                let width = 2 + rng.below(10) as usize;
                let depth = 1 + rng.below(3) as usize;
                let batch = 1 + rng.below(4) as usize;
                let n = 1 + rng.below(4) as usize;
                let kind = ActivationKind::ALL[rng.below(4) as usize];
                let mlp = Mlp::uniform_with(1, width, depth, 1, kind, rng);
                let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, rng);
                (mlp, x, n)
            },
            |(mlp, x, n)| {
                let engine = NtpEngine::new(*n);
                let pure = engine.forward(mlp, x);

                let mut g = Graph::new();
                let xn = g.input(x.shape());
                let pn = mlp.const_param_nodes(&mut g);
                let nodes = engine.forward_graph(&mut g, mlp, xn, &pn, *n);
                let vals = g.eval(&[x.clone()], &nodes);
                for order in 0..=*n {
                    if !allclose_slice(
                        pure[order].data(),
                        vals.get(nodes[order]).data(),
                        1e-11,
                        1e-11,
                    ) {
                        return Err(format!(
                            "{} order {order} mismatch",
                            mlp.activation.name()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// The recorded directional jet must match the pure directional
    /// forward pass for every registered activation (multi-input
    /// networks, per-row directions).
    #[test]
    fn directional_tape_matches_pure_directional_forward() {
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(0xD1 + kind.index() as u64);
            let mlp = Mlp::uniform_with(2, 6, 2, 1, kind, &mut rng);
            let x = Tensor::rand_uniform(&[5, 2], -1.0, 1.0, &mut rng);
            let v = Tensor::rand_uniform(&[5, 2], -1.0, 1.0, &mut rng);
            let n = 3;
            let engine = NtpEngine::new(n);
            let pure = engine.forward_directional(&mlp, &x, &v, n);

            let mut g = Graph::new();
            let pn = mlp.const_param_nodes(&mut g);
            let xn = g.constant(x.clone());
            let vn = g.constant(v.clone());
            let nodes = engine.forward_graph_directional(&mut g, &mlp, xn, vn, &pn, n);
            let vals = g.eval(&[], &nodes);
            for order in 0..=n {
                assert!(
                    allclose_slice(
                        pure[order].data(),
                        vals.get(nodes[order]).data(),
                        1e-11,
                        1e-11
                    ),
                    "{} order {order}",
                    kind.name()
                );
            }
        }
    }

    /// Backprop through the recorded channels must match backprop through
    /// the repeated-autodiff stack: same loss, same parameter gradients —
    /// for every registered activation.
    #[test]
    fn param_gradients_match_autodiff_baseline() {
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(0xAB ^ kind.index() as u64);
            let mlp = Mlp::uniform_with(1, 6, 2, 1, kind, &mut rng);
            let x = Tensor::linspace(-1.0, 1.0, 5).reshape(&[5, 1]);
            let n = 3;

            // n-TangentProp path: single backward over the recorded channels.
            let engine = NtpEngine::new(n);
            let mut g1 = Graph::new();
            let xn1 = g1.input(x.shape());
            let pn1 = mlp.input_param_nodes(&mut g1);
            let ch = engine.forward_graph(&mut g1, &mlp, xn1, &pn1, n);
            // Loss = mean(u''^2) + mean(u'''^2) (a derivative-heavy loss).
            let a = g1.mean_square(ch[2]);
            let b = g1.mean_square(ch[3]);
            let loss1 = g1.add(a, b);
            let grads1 = g1.backward(loss1, &pn1);
            let mut inputs1 = vec![x.clone()];
            inputs1.extend(mlp.param_tensors());
            let vals1 = g1.eval(&inputs1, &grads1);
            let flat1 = params::flatten_tensors(
                &grads1.iter().map(|&id| vals1.get(id).clone()).collect::<Vec<_>>(),
            );
            let l1 = g1.eval(&inputs1, &[loss1]).get(loss1).item();

            // Baseline: repeated autodiff for the channels, then backward.
            let mut g2 = Graph::new();
            let xn2 = g2.input(x.shape());
            let pn2 = mlp.input_param_nodes(&mut g2);
            let u = mlp.forward_graph(&mut g2, xn2, &pn2);
            let stack = crate::autodiff::higher::derivative_stack(&mut g2, u, xn2, n);
            let a2 = g2.mean_square(stack[2]);
            let b2 = g2.mean_square(stack[3]);
            let loss2 = g2.add(a2, b2);
            let grads2 = g2.backward(loss2, &pn2);
            let vals2 = g2.eval(&inputs1, &grads2);
            let flat2 = params::flatten_tensors(
                &grads2.iter().map(|&id| vals2.get(id).clone()).collect::<Vec<_>>(),
            );
            let l2 = g2.eval(&inputs1, &[loss2]).get(loss2).item();

            assert!(
                (l1 - l2).abs() <= 1e-10 * l2.abs().max(1.0),
                "{}: loss {l1} vs {l2}",
                kind.name()
            );
            assert!(
                allclose_slice(flat1.data(), flat2.data(), 1e-7, 1e-9),
                "{}: max diff {}",
                kind.name(),
                crate::util::max_abs_diff(flat1.data(), flat2.data())
            );
        }
    }

    /// Tape size must grow quasilinearly with n (vs exponential for the
    /// repeated-backward baseline) — the memory half of the paper's claim.
    #[test]
    fn tape_growth_quasilinear_vs_autodiff_exponential() {
        let mut rng = Prng::seeded(0xCD);
        let mlp = Mlp::uniform(1, 8, 3, 1, &mut rng);
        let x_shape = [4usize, 1usize];

        let mut ntp_sizes = Vec::new();
        let mut ad_sizes = Vec::new();
        for n in 1..=6 {
            let engine = NtpEngine::new(n);
            let mut g = Graph::new();
            let xn = g.input(&x_shape);
            let pn = mlp.const_param_nodes(&mut g);
            engine.forward_graph(&mut g, &mlp, xn, &pn, n);
            ntp_sizes.push(g.len() as f64);

            let mut g2 = Graph::new();
            let xn2 = g2.input(&x_shape);
            let pn2 = mlp.const_param_nodes(&mut g2);
            let u = mlp.forward_graph(&mut g2, xn2, &pn2);
            crate::autodiff::higher::derivative_stack(&mut g2, u, xn2, n);
            ad_sizes.push(g2.len() as f64);
        }
        // Compare growth ratios at the top end.
        let ntp_ratio = ntp_sizes[5] / ntp_sizes[4];
        let ad_ratio = ad_sizes[5] / ad_sizes[4];
        assert!(
            ntp_ratio < 1.8 && ad_ratio > 2.0,
            "ntp {ntp_sizes:?} ad {ad_sizes:?}"
        );
    }
}
