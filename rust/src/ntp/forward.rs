//! The n-TangentProp forward pass (Algorithm 1 of the paper), pure tensor
//! version — the inference/benchmark hot path.
//!
//! Channel state per layer: `y[i] = d^i z^ℓ / dx^i`, shape `[B, width]`.
//! Crossing an activation applies Faà di Bruno (eq. 5b) using the
//! activation's derivative tower; crossing the affine layer is linear in
//! every channel (eq. 5a), with the bias entering channel 0 only.
//!
//! # Fused element-tiled kernel
//!
//! The quasilinear bound is about op count, but the naive realization is
//! memory-bandwidth bound: every partition term sweeps a full `[B·width]`
//! plane, channel powers are materialized into full-plane scratch, and
//! the affine step issues `n+1` separate small GEMMs. [`NtpEngine`]
//! therefore runs a **fused kernel** instead:
//!
//! - the Faà di Bruno tables are compiled once per engine into a flat
//!   [`FdbProgram`] (coefficients, tower indices, pre-resolved operand
//!   plane ids — no partition walking in the hot loop);
//! - the batch is processed in 128-element tiles: all `n+1`
//!   channels, the activation tower, the channel powers and the ξ
//!   accumulators for one tile are packed contiguously in a tile-local
//!   workspace, so the whole combine happens in one L1-resident sweep
//!   with no full-plane scratch traffic;
//! - channel state is kept in a *stacked* layout (`[(n+1)·B, width]`,
//!   channel `k` a contiguous plane), so the affine step is a **single
//!   stacked-channel GEMM** through the blocked kernel in
//!   [`crate::tensor::linalg::matmul_nt_block_into`], with the bias
//!   added to channel 0's rows only;
//! - every hot loop of the sweep — the seed rows, the power fills, the
//!   interpreter's 1/2/k-factor paths, the tower algebra, the GEMM
//!   microkernel and the bias rows — dispatches through the runtime-
//!   selected [`crate::simd::Isa`] vector kernels, captured once at
//!   engine construction. Scalar and vector kernels are bitwise
//!   identical (see the `simd` module docs), so the choice of ISA never
//!   changes results.
//!
//! The pre-fusion pass survives as `NtpEngine::forward_reference` behind
//! the `reference-oracle` cargo feature, for differential testing and as
//! the benchmark baseline.
//!
//! The batch dimension is embarrassingly parallel — every output row
//! depends only on its input row, with no cross-row reductions — so
//! [`NtpEngine::forward_n`] can split the batch into row chunks and run
//! them on scoped worker threads under a [`ParallelPolicy`]. Every
//! per-element/per-row value the fused kernel computes is independent of
//! the element's position in a tile and of the tile boundaries, and every
//! stacked-GEMM output element accumulates in a fixed ascending-k order,
//! so chunked execution performs the exact same floating-point operations
//! per row as the serial pass and parallel output is *bitwise identical*
//! to serial output (locked down by `rust/tests/parallel_determinism.rs`).

use super::activation::{ActivationKind, SmoothActivation};
use super::bell::{FaaDiBruno, FdbProgram};
use crate::nn::Mlp;
use crate::obs::{KernelPhase, PhaseAccum};
use crate::simd::Isa;
use crate::tensor::linalg::matmul_nt_block_into_with;
use crate::tensor::Tensor;
use std::sync::Mutex;

/// How [`NtpEngine::forward_n`] distributes the batch across threads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ParallelPolicy {
    /// One thread — the seed behaviour and the default.
    #[default]
    Serial,
    /// Exactly this many worker threads (clamped to the batch size).
    Fixed(usize),
    /// Use `std::thread::available_parallelism()`, engaging only when
    /// the batch is large enough to amortize thread-spawn cost.
    Auto,
}

/// Batches smaller than this stay serial under [`ParallelPolicy::Auto`]
/// (per-row work at moderate `n` is a few µs; spawning costs ~10 µs).
const AUTO_MIN_ROWS_PER_WORKER: usize = 128;

/// Elements per fused-kernel tile. At 128 elements the whole tile
/// workspace (towers + channels + powers + ξ, ≤ ~40 planes at n = 9) is
/// ≤ ~40 KB — L1/L2-resident — while each plane is still long enough for
/// the per-term loops to vectorize.
const TILE: usize = 128;

impl ParallelPolicy {
    /// Upper bound on worker threads this policy allows (`Auto` = the
    /// machine's available parallelism), before any per-call-site
    /// clamping. Single source of the policy → thread-count decoding,
    /// shared with the training path's [`crate::util::par`].
    pub fn thread_cap(self) -> usize {
        match self {
            ParallelPolicy::Serial => 1,
            ParallelPolicy::Fixed(t) => t.max(1),
            ParallelPolicy::Auto => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }

    /// Worker count for a batch of `batch` rows (1 means "run serial").
    pub fn workers_for(self, batch: usize) -> usize {
        let cap = match self {
            // Per-row work at moderate n is a few µs, so Auto only
            // engages once every worker gets a meaty chunk of rows.
            ParallelPolicy::Auto => self.thread_cap().min(batch / AUTO_MIN_ROWS_PER_WORKER),
            _ => self.thread_cap(),
        };
        cap.max(1).min(batch.max(1))
    }
}

/// Engine with precomputed Faà di Bruno + activation-tower tables and a
/// compiled fused-kernel program for up to `n_max` derivatives.
///
/// The engine is `Send + Sync`: all tables are immutable after
/// construction and the reusable workspaces live in a mutex-guarded pool
/// (one scratch per concurrently active worker), so a single engine can
/// be shared by reference across threads.
pub struct NtpEngine {
    n_max: usize,
    fdb: FaaDiBruno,
    /// The Faà di Bruno tables compiled to the fused kernel's flat
    /// instruction format (built once here, interpreted per tile).
    program: FdbProgram,
    /// One tower evaluator per registered activation, indexed by
    /// [`ActivationKind::index`].
    acts: Vec<Box<dyn SmoothActivation>>,
    /// How `forward_n` splits the batch across threads.
    policy: ParallelPolicy,
    /// The SIMD kernel set the fused sweeps dispatch to — resolved once
    /// at construction from [`Isa::active`] (results are bitwise
    /// ISA-independent, so this only affects speed).
    isa: Isa,
    /// §Perf: pool of reusable hot-loop buffers (stacked channel planes,
    /// the tile workspace, and the reference path's power/ξ tensors), so
    /// repeated forward calls allocate only the tensors they return.
    /// Workers pop a scratch on entry and push it back on exit; the pool
    /// grows to the peak concurrency ever used.
    scratch_pool: Mutex<Vec<Scratch>>,
}

/// Reusable buffers for [`NtpEngine::forward_n`] (fused path) and
/// `NtpEngine::forward_reference` (pre-fusion path, feature-gated).
#[derive(Default)]
struct Scratch {
    /// Fused path: stacked channel state, channel `k` of the current
    /// layer occupying the contiguous plane `[k·B·w .. (k+1)·B·w]`.
    stack_cur: Vec<f64>,
    /// Fused path: combine output (pre-GEMM) stacked buffer.
    stack_nxt: Vec<f64>,
    /// Fused path: tile workspace — tower planes, then the program's
    /// operand planes (channels + powers), then the ξ accumulators, then
    /// one spare product plane for the k-factor interpreter path, each
    /// [`TILE`] elements.
    tile: Vec<f64>,
    /// Directional path: the `[x; v]` row-stacked seed operand, so both
    /// seed products run as one GEMM launch.
    dir_seed: Vec<f64>,
    /// Reference path: `powers[j][c-2] = y_j^c` for multiplicities
    /// `c ≥ 2` (the power-1 "entry" borrows `y_j` directly).
    #[cfg(feature = "reference-oracle")]
    powers: Vec<Vec<Tensor>>,
    /// Reference path: `xi[i]` accumulates the combine for channel `i`.
    #[cfg(feature = "reference-oracle")]
    xi: Vec<Tensor>,
}

/// Grow `buf` to at least `len` elements (zero-filled growth; existing
/// contents are irrelevant — the kernels write before reading).
fn ensure_len(buf: &mut Vec<f64>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// Make `buf` a zeroed tensor of `shape`, reusing its allocation when the
/// shape already matches.
#[cfg(feature = "reference-oracle")]
fn ensure_zeroed(buf: &mut Tensor, shape: &[usize]) {
    if buf.shape() == shape {
        buf.data_mut().fill(0.0);
    } else {
        *buf = Tensor::zeros(shape);
    }
}

/// Copy rows `lo..hi` of a rank-2 tensor into a fresh tensor.
fn slice_rows(x: &Tensor, lo: usize, hi: usize) -> Tensor {
    let d = x.shape()[1];
    Tensor::from_vec(x.data()[lo * d..hi * d].to_vec(), &[hi - lo, d])
}

/// Row-chunk `batch` across `workers` scoped threads and stitch the
/// per-chunk channel blocks back in order. Chunk 0 runs inline on the
/// calling thread (which would otherwise idle in join), so `Fixed(t)`
/// spawns t-1 threads and uses exactly t cores. Chunk boundaries are a
/// pure function of `(batch, workers)` and every per-row value is
/// independent of its chunk, so the stitched output is bitwise identical
/// to a serial pass.
fn parallel_channels<F>(
    batch: usize,
    out_dim: usize,
    n: usize,
    workers: usize,
    eval: F,
) -> Vec<Tensor>
where
    F: Fn(usize, usize) -> Vec<Tensor> + Sync,
{
    let rows = batch.div_ceil(workers);
    let ranges: Vec<(usize, usize)> = (0..workers)
        .filter_map(|w| {
            let lo = w * rows;
            if lo >= batch {
                return None;
            }
            Some((lo, (lo + rows).min(batch)))
        })
        .collect();
    let results: Vec<Vec<Tensor>> = std::thread::scope(|s| {
        let eval = &eval;
        let handles: Vec<_> = ranges[1..]
            .iter()
            .map(|&(lo, hi)| s.spawn(move || eval(lo, hi)))
            .collect();
        let mut results = Vec::with_capacity(ranges.len());
        results.push(eval(ranges[0].0, ranges[0].1));
        for h in handles {
            results.push(h.join().expect("ntp worker panicked"));
        }
        results
    });
    (0..=n)
        .map(|k| {
            let mut out = Tensor::zeros(&[batch, out_dim]);
            let dst = out.data_mut();
            let mut off = 0;
            for r in &results {
                let src = r[k].data();
                dst[off..off + src.len()].copy_from_slice(src);
                off += src.len();
            }
            out
        })
        .collect()
}

/// The data slice for `y_j^c`: multiplicity 1 borrows the channel itself,
/// higher multiplicities come from the scratch power cache.
#[cfg(feature = "reference-oracle")]
fn power_slice<'a>(y: &'a [Tensor], powers: &'a [Vec<Tensor>], j: usize, c: usize) -> &'a [f64] {
    if c == 1 {
        y[j].data()
    } else {
        powers[j][c - 2].data()
    }
}

impl NtpEngine {
    /// Build tables for up to `n_max` derivatives (all registered
    /// activations), serial execution.
    pub fn new(n_max: usize) -> NtpEngine {
        NtpEngine::with_policy(n_max, ParallelPolicy::Serial)
    }

    /// Build tables for up to `n_max` derivatives with an explicit
    /// batch-parallelism policy. The SIMD kernel set is resolved once
    /// here from [`Isa::active`] (`NTANGENT_SIMD` / CPU detection).
    pub fn with_policy(n_max: usize, policy: ParallelPolicy) -> NtpEngine {
        NtpEngine::with_isa(n_max, policy, Isa::active())
    }

    /// [`NtpEngine::with_policy`] with an explicitly pinned [`Isa`]
    /// instead of the process-wide one — lets tests compare the scalar
    /// and vector kernel sets in one process. Results are bitwise
    /// identical across ISAs; only throughput differs.
    pub fn with_isa(n_max: usize, policy: ParallelPolicy, isa: Isa) -> NtpEngine {
        let fdb = FaaDiBruno::new(n_max);
        let program = FdbProgram::compile(&fdb);
        NtpEngine {
            n_max,
            fdb,
            program,
            acts: ActivationKind::ALL
                .iter()
                .map(|k| k.build_tower(n_max))
                .collect(),
            policy,
            isa,
            scratch_pool: Mutex::new(Vec::new()),
        }
    }

    /// The SIMD kernel set this engine dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Highest derivative order the tables cover.
    pub fn n_max(&self) -> usize {
        self.n_max
    }

    /// The batch-parallelism policy.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// Change the batch-parallelism policy (output stays bitwise
    /// identical — chunking only changes scheduling).
    pub fn set_policy(&mut self, policy: ParallelPolicy) {
        self.policy = policy;
    }

    /// The precomputed Faà di Bruno tables.
    pub fn tables(&self) -> &FaaDiBruno {
        &self.fdb
    }

    /// The compiled fused-kernel program.
    pub fn program(&self) -> &FdbProgram {
        &self.program
    }

    /// The tower evaluator for a registered activation.
    pub fn act_for(&self, kind: ActivationKind) -> &dyn SmoothActivation {
        self.acts[kind.index()].as_ref()
    }

    /// Compute `[u, u', ..., u^(n_max)]` for `x: [B, 1]`.
    pub fn forward(&self, mlp: &Mlp, x: &Tensor) -> Vec<Tensor> {
        self.forward_n(mlp, x, self.n_max)
    }

    /// Compute the **directional jet** `[u, D_v u, ..., D_v^n u]` where
    /// `D_v^k u = d^k/dt^k u(x + t·v) |_{t=0}`, for a multi-input network
    /// (`x: [B, d]`) with one direction per row (`v: [B, d]`).
    ///
    /// The curve `t ↦ f(x + t·v)` is scalar-to-scalar, so the whole
    /// univariate channel algebra — Faà di Bruno combine, fused tiles,
    /// stacked GEMM — applies unchanged; only the channel *seeding*
    /// differs: `y1 = v W0^T` (the chain rule through the first affine
    /// layer) instead of `y1 = 1 W0^T`. This is the engine primitive
    /// behind [`crate::ntp::multi::MultiJetEngine`], which batches `D`
    /// directions into one `[D·B, d]` call and recombines the jets into
    /// exact mixed partials.
    ///
    /// Under a non-serial [`ParallelPolicy`] the rows are chunked across
    /// scoped threads exactly like [`NtpEngine::forward_n`], with bitwise
    /// identical output.
    ///
    /// ```
    /// use ntangent::nn::Mlp;
    /// use ntangent::ntp::NtpEngine;
    /// use ntangent::tensor::Tensor;
    /// use ntangent::util::prng::Prng;
    ///
    /// let mut rng = Prng::seeded(2);
    /// let mlp = Mlp::uniform(2, 8, 2, 1, &mut rng); // 2-D input
    /// let x = Tensor::rand_uniform(&[16, 2], -1.0, 1.0, &mut rng);
    /// let ex = Tensor::from_vec([1.0, 0.0].repeat(16), &[16, 2]);
    /// let engine = NtpEngine::new(3);
    /// let jet = engine.forward_directional(&mlp, &x, &ex, 2);
    /// assert_eq!(jet.len(), 3); // [u, ∂u/∂x₀, ∂²u/∂x₀²]
    /// assert_eq!(jet[0].shape(), &[16, 1]);
    /// ```
    pub fn forward_directional(&self, mlp: &Mlp, x: &Tensor, v: &Tensor, n: usize) -> Vec<Tensor> {
        assert!(n <= self.n_max, "n={n} exceeds engine n_max={}", self.n_max);
        assert_eq!(x.rank(), 2, "x must be [B, d]");
        assert_eq!(v.shape(), x.shape(), "one direction row per point row");
        assert_eq!(
            mlp.input_dim(),
            x.shape()[1],
            "network input dim must match the point dim"
        );
        let _span = crate::obs::span("ntp.forward_directional");
        let batch = x.shape()[0];
        let workers = self.policy.workers_for(batch);
        if workers <= 1 {
            let mut scratch = self.take_scratch();
            let out = self.forward_directional_chunk(mlp, x, v, n, &mut scratch);
            self.put_scratch(scratch);
            out
        } else {
            parallel_channels(batch, mlp.output_dim(), n, workers, |lo, hi| {
                let xc = slice_rows(x, lo, hi);
                let vc = slice_rows(v, lo, hi);
                let mut scratch = self.take_scratch();
                let out = self.forward_directional_chunk(mlp, &xc, &vc, n, &mut scratch);
                self.put_scratch(scratch);
                out
            })
        }
    }

    /// Shared argument validation of the forward entry points.
    fn check_forward_args(&self, mlp: &Mlp, x: &Tensor, n: usize) {
        assert!(n <= self.n_max, "n={n} exceeds engine n_max={}", self.n_max);
        assert_eq!(x.rank(), 2, "x must be [B, 1]");
        assert_eq!(x.shape()[1], 1, "n-TangentProp propagates d/dx of a scalar input");
        assert_eq!(mlp.input_dim(), 1, "network input dim must be 1");
    }

    /// Compute `[u, u', ..., u^(n)]` for `n <= n_max` with the fused
    /// element-tiled kernel.
    ///
    /// Single forward pass; all channels advance together (the paper's
    /// headline algorithm). Under a non-serial [`ParallelPolicy`] the
    /// batch is chunked row-wise across scoped worker threads; the result
    /// is bitwise identical to the serial pass.
    ///
    /// ```
    /// use ntangent::nn::Mlp;
    /// use ntangent::ntp::{NtpEngine, ParallelPolicy};
    /// use ntangent::tensor::Tensor;
    /// use ntangent::util::prng::Prng;
    ///
    /// let mut rng = Prng::seeded(1);
    /// let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
    /// let x = Tensor::linspace(-1.0, 1.0, 64).reshape(&[64, 1]);
    /// let engine = NtpEngine::with_policy(4, ParallelPolicy::Fixed(2));
    /// let channels = engine.forward_n(&mlp, &x, 3); // [u, u', u'', u''']
    /// assert_eq!(channels.len(), 4);
    /// assert_eq!(channels[0].shape(), &[64, 1]);
    /// // Chunked execution is bitwise identical to the serial engine:
    /// assert_eq!(channels, NtpEngine::new(3).forward_n(&mlp, &x, 3));
    /// ```
    pub fn forward_n(&self, mlp: &Mlp, x: &Tensor, n: usize) -> Vec<Tensor> {
        self.check_forward_args(mlp, x, n);
        // Caller-level span only: worker threads spawned below carry no
        // spans (fresh thread-local stacks per call would allocate in the
        // warm path); their cost shows up in the kernel-phase counters.
        let _span = crate::obs::span("ntp.forward_n");
        let workers = self.policy.workers_for(x.shape()[0]);
        if workers <= 1 {
            self.forward_chunk_pooled(mlp, x, n)
        } else {
            self.forward_parallel(mlp, x, n, workers)
        }
    }

    /// The pre-fusion n-TangentProp pass — term-major full-plane sweeps
    /// with materialized channel powers and one affine matmul per channel
    /// — kept as the fused kernel's differential-testing oracle and as
    /// the benchmark baseline (`ntangent bench kernels`). Always serial,
    /// always on the scalar kernels, and compiled only under the
    /// `reference-oracle` cargo feature (it is not a production path).
    #[cfg(feature = "reference-oracle")]
    pub fn forward_reference(&self, mlp: &Mlp, x: &Tensor, n: usize) -> Vec<Tensor> {
        self.check_forward_args(mlp, x, n);
        let mut scratch = self.take_scratch();
        let out = self.forward_reference_chunk(mlp, x, n, &mut scratch);
        self.put_scratch(scratch);
        out
    }

    /// Row-chunk the batch across `workers` scoped threads, each with its
    /// own pooled scratch, and stitch the channel blocks back in order.
    fn forward_parallel(&self, mlp: &Mlp, x: &Tensor, n: usize, workers: usize) -> Vec<Tensor> {
        parallel_channels(x.shape()[0], mlp.output_dim(), n, workers, |lo, hi| {
            self.forward_chunk_pooled(mlp, &slice_rows(x, lo, hi), n)
        })
    }

    /// One chunk's forward with a scratch borrowed from the pool.
    fn forward_chunk_pooled(&self, mlp: &Mlp, x: &Tensor, n: usize) -> Vec<Tensor> {
        let mut scratch = self.take_scratch();
        let out = self.forward_chunk(mlp, x, n, &mut scratch);
        self.put_scratch(scratch);
        out
    }

    fn take_scratch(&self) -> Scratch {
        self.scratch_pool
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_default()
    }

    fn put_scratch(&self, scratch: Scratch) {
        self.scratch_pool
            .lock()
            .expect("scratch pool poisoned")
            .push(scratch);
    }

    /// Size the pooled buffers for one `batch`-row call: stacked channel
    /// planes at the widest layer plus the tile workspace (laid out by
    /// `n_max` so one scratch serves every call; the `+ 1` is the spare
    /// product plane of the interpreter's k-factor path).
    fn ensure_scratch(&self, mlp: &Mlp, batch: usize, n: usize, scratch: &mut Scratch) {
        let nch = n + 1;
        let ch_base = self.n_max + 1;
        let xi_base = ch_base + self.program.n_operands();
        let tile_planes = xi_base + self.n_max + 1;
        let w_max = mlp.layers.iter().map(|l| l.fan_out()).max().unwrap();
        ensure_len(&mut scratch.stack_cur, nch * batch * w_max);
        ensure_len(&mut scratch.stack_nxt, nch * batch * w_max);
        ensure_len(&mut scratch.tile, tile_planes * TILE);
    }

    /// The fused serial pass over one (chunk of a) batch.
    ///
    /// §Perf: the only tensor allocations are the `n+1` returned
    /// channels; everything else lives in the pooled scratch. Every
    /// per-element value is a function of that element's inputs alone
    /// (tile boundaries never enter the arithmetic), which is what makes
    /// row-chunked execution bitwise identical to serial.
    fn forward_chunk(&self, mlp: &Mlp, x: &Tensor, n: usize, scratch: &mut Scratch) -> Vec<Tensor> {
        let batch = x.shape()[0];
        self.ensure_scratch(mlp, batch, n, scratch);

        // First affine layer seeds the channels:
        //   y0 = x W^T + b, y1 = 1 W^T (d x/dx = 1), y_i = 0 for i >= 2.
        let l0 = &mlp.layers[0];
        let w0 = l0.fan_out();
        {
            let isa = self.isa;
            let cur = &mut scratch.stack_cur;
            let wd = l0.w.data(); // [w0, 1] row-major = one weight per row
            let bd = l0.b.data();
            let plane = batch * w0;
            for (row, &xv) in cur[..plane].chunks_exact_mut(w0).zip(x.data()) {
                isa.axpb_into(row, xv, wd, bd);
            }
            if n >= 1 {
                for row in cur[plane..2 * plane].chunks_exact_mut(w0) {
                    row.copy_from_slice(wd);
                }
            }
            for k in 2..=n {
                cur[k * plane..(k + 1) * plane].fill(0.0);
            }
        }
        self.propagate_layers(mlp, batch, n, scratch)
    }

    /// Directional twin of [`NtpEngine::forward_chunk`]: seed the
    /// channels for the curve `t ↦ f(x + t·v)` —
    /// `y0 = x W0^T + b0`, `y1 = v W0^T`, `y_i = 0` for `i ≥ 2` — then
    /// run the same fused layer propagation. The two seed products run
    /// as a single `[x; v]`-stacked GEMM launch (bitwise identical to
    /// two launches by the blocked kernel's row-chunk invariance).
    fn forward_directional_chunk(
        &self,
        mlp: &Mlp,
        x: &Tensor,
        v: &Tensor,
        n: usize,
        scratch: &mut Scratch,
    ) -> Vec<Tensor> {
        let batch = x.shape()[0];
        let d = x.shape()[1];
        self.ensure_scratch(mlp, batch, n, scratch);

        let l0 = &mlp.layers[0];
        let w0 = l0.fan_out();
        let plane = batch * w0;
        {
            let isa = self.isa;
            // Both seed products — y0 = x W0^T and y1 = v W0^T — share
            // the weight operand, so stack `[x; v]` row-wise and launch
            // ONE GEMM writing channels 0 and 1 back to back. The
            // blocked kernel is row-chunk invariant bitwise (see
            // `blocked_nt_matmul_is_row_chunk_invariant_bitwise`), so
            // the fold reproduces the two separate launches exactly.
            let cur = &mut scratch.stack_cur;
            if n >= 1 {
                let seed = &mut scratch.dir_seed;
                ensure_len(seed, 2 * batch * d);
                seed[..batch * d].copy_from_slice(x.data());
                seed[batch * d..2 * batch * d].copy_from_slice(v.data());
                matmul_nt_block_into_with(
                    isa,
                    &seed[..2 * batch * d],
                    l0.w.data(),
                    &mut cur[..2 * plane],
                    2 * batch,
                    d,
                    w0,
                );
            } else {
                matmul_nt_block_into_with(isa, x.data(), l0.w.data(), &mut cur[..plane], batch, d, w0);
            }
            // Bias enters channel 0's rows only.
            let bd = l0.b.data();
            for row in cur[..plane].chunks_exact_mut(w0) {
                isa.add_assign(row, bd);
            }
            for k in 2..=n {
                cur[k * plane..(k + 1) * plane].fill(0.0);
            }
        }
        self.propagate_layers(mlp, batch, n, scratch)
    }

    /// Advance pre-seeded stacked channels (channel `k` of the first
    /// layer's output occupying `stack_cur[k·batch·w0 ..]`) through the
    /// remaining layers with the fused element-tiled kernel and return
    /// the `n+1` output channels — shared by the scalar and the
    /// directional seeds.
    fn propagate_layers(
        &self,
        mlp: &Mlp,
        batch: usize,
        n: usize,
        scratch: &mut Scratch,
    ) -> Vec<Tensor> {
        let act = self.act_for(mlp.activation);
        let prog = &self.program;
        let isa = self.isa;
        let nch = n + 1;
        // Sampled kernel-phase profiling (crate::obs). The accumulator
        // only reads clocks and stack-local integers — it never touches
        // the float planes — so traced output is bitwise identical to
        // untraced output; disabled, it costs one branch per tile.
        let mut acc = PhaseAccum::new();

        // Tile plane bases: towers first, then the program's operand
        // planes (channels + powers), then the ξ accumulators (a spare
        // product plane for the k-factor path sits past those).
        let ch_base = self.n_max + 1;
        let xi_base = ch_base + prog.n_operands();

        let mut width = mlp.layers[0].fan_out();
        for layer in &mlp.layers[1..] {
            let w_in = width;
            let w_out = layer.fan_out();
            let plane = batch * w_in;

            // ---- fused activation/combine sweep over element tiles ----
            {
                let cur = &scratch.stack_cur;
                let nxt = &mut scratch.stack_nxt;
                let tile = &mut scratch.tile;
                let mut t0 = 0;
                while t0 < plane {
                    let len = TILE.min(plane - t0);
                    let mut pt = acc.tile();
                    // Pack this tile's channel slices contiguously.
                    for k in 0..nch {
                        let dst = (ch_base + k) * TILE;
                        let src = k * plane + t0;
                        tile[dst..dst + len].copy_from_slice(&cur[src..src + len]);
                    }
                    acc.lap(&mut pt, KernelPhase::Pack);
                    // Activation tower σ^{(0..=n)}(y0) into the tower planes.
                    {
                        let (towers, operands) = tile.split_at_mut(ch_base * TILE);
                        act.tower_into(&operands[..len], n, towers, TILE, isa);
                    }
                    acc.lap(&mut pt, KernelPhase::Tower);
                    // Channel powers y_j^c, built plane-by-plane in L1.
                    {
                        let operands = &mut tile[ch_base * TILE..xi_base * TILE];
                        for f in prog.fills(n) {
                            let (lo, hi) = operands.split_at_mut(f.dst as usize * TILE);
                            let ao = f.a as usize * TILE;
                            let bo = f.b as usize * TILE;
                            let (a, b) = (&lo[ao..ao + len], &lo[bo..bo + len]);
                            isa.mul_into(&mut hi[..len], a, b);
                        }
                    }
                    acc.lap(&mut pt, KernelPhase::Powers);
                    // ξ_i = Σ_{p∈P(i)} C_p σ^{(|p|)}(y0) Π_j y_j^{p_j}
                    // (eq. 5b), interpreted from the compiled program with
                    // everything tile-resident.
                    {
                        let (head_mut, rest) = tile.split_at_mut(xi_base * TILE);
                        let head: &[f64] = head_mut;
                        let (xi_region, tmp_plane) = rest.split_at_mut(self.n_max * TILE);
                        let tmp = &mut tmp_plane[..len];
                        for i in 1..=n {
                            let xi = &mut xi_region[(i - 1) * TILE..(i - 1) * TILE + len];
                            xi.fill(0.0);
                            for op in prog.ops(i) {
                                let coeff = op.coeff;
                                let to = op.tower as usize * TILE;
                                let tw = &head[to..to + len];
                                let fids = prog.factor_ids(op);
                                match fids {
                                    [a] => {
                                        let ao = (ch_base + *a as usize) * TILE;
                                        isa.xi_acc1(xi, coeff, tw, &head[ao..ao + len]);
                                    }
                                    [a, b] => {
                                        let ao = (ch_base + *a as usize) * TILE;
                                        let bo = (ch_base + *b as usize) * TILE;
                                        isa.xi_acc2(
                                            xi,
                                            coeff,
                                            tw,
                                            &head[ao..ao + len],
                                            &head[bo..bo + len],
                                        );
                                    }
                                    _ => {
                                        // Same left-to-right product order
                                        // as the historical scalar loop:
                                        // p = coeff·t, then p *= factor.
                                        isa.scale_into(tmp, coeff, tw);
                                        for &fid in fids {
                                            let fo = (ch_base + fid as usize) * TILE;
                                            isa.mul_assign(tmp, &head[fo..fo + len]);
                                        }
                                        isa.add_assign(xi, tmp);
                                    }
                                }
                            }
                        }
                    }
                    acc.lap(&mut pt, KernelPhase::Interpret);
                    // Unpack: σ(y0) becomes channel 0, ξ_i channel i.
                    nxt[t0..t0 + len].copy_from_slice(&tile[..len]);
                    for i in 1..=n {
                        let so = (xi_base + i - 1) * TILE;
                        nxt[i * plane + t0..i * plane + t0 + len]
                            .copy_from_slice(&tile[so..so + len]);
                    }
                    acc.lap(&mut pt, KernelPhase::Unpack);
                    t0 += len;
                }
            }

            // ---- stacked-channel GEMM: all n+1 channels in one matmul,
            // bias entering channel 0's rows only ----
            {
                let mut gt = acc.start();
                let a = &scratch.stack_nxt[..nch * plane];
                let c = &mut scratch.stack_cur[..nch * batch * w_out];
                matmul_nt_block_into_with(isa, a, layer.w.data(), c, nch * batch, w_in, w_out);
                let bd = layer.b.data();
                if w_out > 0 {
                    for row in c[..batch * w_out].chunks_exact_mut(w_out) {
                        isa.add_assign(row, bd);
                    }
                }
                acc.lap(&mut gt, KernelPhase::Gemm);
            }
            width = w_out;
        }
        acc.flush();

        // The stacked planes of the final layer are the output channels.
        let plane = batch * width;
        let cur = &scratch.stack_cur;
        (0..=n)
            .map(|k| Tensor::from_vec(cur[k * plane..(k + 1) * plane].to_vec(), &[batch, width]))
            .collect()
    }

    /// The pre-fusion serial pass over one batch (see
    /// `NtpEngine::forward_reference`).
    #[cfg(feature = "reference-oracle")]
    fn forward_reference_chunk(
        &self,
        mlp: &Mlp,
        x: &Tensor,
        n: usize,
        scratch: &mut Scratch,
    ) -> Vec<Tensor> {
        let batch = x.shape()[0];
        let act = self.act_for(mlp.activation);

        // First affine layer seeds the channels:
        //   y0 = x W^T + b, y1 = 1 W^T (d x/dx = 1), y_i = 0 for i >= 2.
        let l0 = &mlp.layers[0];
        let mut y: Vec<Tensor> = Vec::with_capacity(n + 1);
        y.push(l0.apply(x));
        if n >= 1 {
            y.push(Tensor::ones(&[batch, 1]).matmul_nt(&l0.w));
        }
        for _ in 2..=n {
            y.push(Tensor::zeros(y[0].shape()));
        }

        for layer in &mlp.layers[1..] {
            // Activation tower σ^(s)(y0), s = 0..=n, one transcendental
            // evaluation per element.
            let towers = act.tower(&y[0], n);
            // Precompute the channel powers y_j^c every partition term
            // needs (2 ≤ c ≤ n/j) into the reusable scratch, once per
            // layer. Power 1 is read straight from `y` — no clone.
            let sc = &mut *scratch;
            Self::fill_powers(&mut sc.powers, &y, n);
            // Faà di Bruno combine into the scratch outputs; every ξ_i
            // consumes pre-update channels, so `y` stays untouched until
            // the swap below.
            if sc.xi.len() < n + 1 {
                sc.xi.resize_with(n + 1, || Tensor::zeros(&[0]));
            }
            for i in 1..=n {
                ensure_zeroed(&mut sc.xi[i], towers[0].shape());
                Self::combine_channel(&self.fdb, i, &towers, &y, &sc.powers, &mut sc.xi[i]);
            }
            for i in 1..=n {
                std::mem::swap(&mut y[i], &mut sc.xi[i]);
            }
            // Affine layer: channel 0 gets the bias, others are linear.
            let h0 = layer.apply(&towers[0]);
            for item in y.iter_mut().skip(1) {
                *item = layer.apply_linear(item);
            }
            y[0] = h0;
        }
        y
    }

    /// Fill `powers[j][c-2] = y_j^c` for every multiplicity `c ≥ 2` any
    /// partition term of order ≤ n can request (`c ≤ n/j`), reusing the
    /// scratch tensors across layers and calls.
    #[cfg(feature = "reference-oracle")]
    fn fill_powers(powers: &mut Vec<Vec<Tensor>>, y: &[Tensor], n: usize) {
        if powers.len() < n + 1 {
            powers.resize_with(n + 1, Vec::new);
        }
        for (j, yj) in y.iter().enumerate().skip(1) {
            let c_max = if j <= n { n / j } else { 0 };
            let row = &mut powers[j];
            let needed = c_max.saturating_sub(1);
            if row.len() < needed {
                row.resize_with(needed, || Tensor::zeros(&[0]));
            }
            if needed == 0 {
                continue;
            }
            for buf in row.iter_mut().take(needed) {
                ensure_zeroed(buf, yj.shape());
            }
            let mut slices: Vec<&mut [f64]> =
                row.iter_mut().take(needed).map(|t| t.data_mut()).collect();
            for (e, &v) in yj.data().iter().enumerate() {
                let mut acc = v;
                for s in slices.iter_mut() {
                    acc *= v;
                    s[e] = acc;
                }
            }
        }
    }

    /// ξ_i = Σ_{p∈P(i)} C_p σ^{(|p|)}(y0) Π_j y_j^{p_j}   (eq. 5b),
    /// accumulated into `out` (already zeroed) — the reference path's
    /// term-major, full-plane combine.
    #[cfg(feature = "reference-oracle")]
    fn combine_channel(
        fdb: &FaaDiBruno,
        i: usize,
        towers: &[Tensor],
        y: &[Tensor],
        powers: &[Vec<Tensor>],
        out: &mut Tensor,
    ) {
        let len = towers[0].numel();
        let zd = out.data_mut();
        for term in fdb.terms(i) {
            let tower = towers[term.outer_order].data();
            let coeff = term.coeff;
            match term.factors.as_slice() {
                [(j, c)] => {
                    let a = power_slice(y, powers, *j, *c);
                    for e in 0..len {
                        zd[e] += coeff * tower[e] * a[e];
                    }
                }
                [(j1, c1), (j2, c2)] => {
                    let a = power_slice(y, powers, *j1, *c1);
                    let b = power_slice(y, powers, *j2, *c2);
                    for e in 0..len {
                        zd[e] += coeff * tower[e] * a[e] * b[e];
                    }
                }
                factors => {
                    let slices: Vec<&[f64]> = factors
                        .iter()
                        .map(|&(j, c)| power_slice(y, powers, j, c))
                        .collect();
                    for e in 0..len {
                        let mut prod = coeff * tower[e];
                        for s in &slices {
                            prod *= s[e];
                        }
                        zd[e] += prod;
                    }
                }
            }
        }
    }

    /// Number of *tensor ops* the forward pass executes for order `n` and
    /// `depth` hidden layers — the quasilinear `O(n·p(n)·L)` work factor
    /// the benchmark reports annotate.
    pub fn op_count(&self, n: usize, depth: usize) -> usize {
        let combine: usize = (1..=n)
            .map(|i| {
                self.fdb
                    .terms(i)
                    .iter()
                    .map(|t| 1 + t.factors.len())
                    .sum::<usize>()
            })
            .sum();
        depth * (combine + (n + 1) /* tower + matmuls */ + (n + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{higher, Graph};
    use crate::tensor::alloc;
    use crate::util::prng::Prng;
    use crate::util::{allclose_slice, ptest};

    /// The paper's central claim, as a property: n-TangentProp equals the
    /// repeated-autodiff derivative stack *exactly* (both are exact
    /// methods), across random architectures and batches — for **every**
    /// registered activation.
    #[test]
    fn matches_repeated_autodiff_exactly() {
        for kind in ActivationKind::ALL {
            ptest::check(
                ptest::Config { cases: 12, seed: 0x5EED ^ kind.index() as u64 },
                |rng: &mut Prng| {
                    let width = 2 + rng.below(12) as usize;
                    let depth = 1 + rng.below(3) as usize;
                    let batch = 1 + rng.below(5) as usize;
                    let n = 1 + rng.below(5) as usize;
                    let mlp = Mlp::uniform_with(1, width, depth, 1, kind, rng);
                    let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, rng);
                    (mlp, x, n)
                },
                |(mlp, x, n)| {
                    let engine = NtpEngine::new(*n);
                    let ntp = engine.forward(mlp, x);

                    let mut g = Graph::new();
                    let xn = g.input(x.shape());
                    let pn = mlp.const_param_nodes(&mut g);
                    let u = mlp.forward_graph(&mut g, xn, &pn);
                    let stack = higher::derivative_stack(&mut g, u, xn, *n);
                    let vals = g.eval(&[x.clone()], &stack);

                    for order in 0..=*n {
                        let a = ntp[order].data();
                        let b = vals.get(stack[order]).data();
                        if !allclose_slice(a, b, 1e-9, 1e-9) {
                            return Err(format!(
                                "{} order {order}: ntp {:?} vs autodiff {:?}",
                                mlp.activation.name(),
                                &a[..a.len().min(4)],
                                &b[..b.len().min(4)]
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    /// The fused kernel against the pre-fusion reference path — the
    /// in-crate differential smoke (the full property sweep lives in
    /// `rust/tests/fused_kernel.rs`). Rides the `reference-oracle`
    /// feature with the oracle it exercises.
    #[cfg(feature = "reference-oracle")]
    #[test]
    fn fused_matches_reference_path() {
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(0xF5D + kind.index() as u64);
            let mlp = Mlp::uniform_with(1, 20, 3, 1, kind, &mut rng);
            let engine = NtpEngine::new(6);
            // Batches straddling the tile size on the [B·width] plane.
            for batch in [1usize, 5, 6, 7, 33] {
                let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, &mut rng);
                let fused = engine.forward_n(&mlp, &x, 6);
                let reference = engine.forward_reference(&mlp, &x, 6);
                for (k, (a, b)) in fused.iter().zip(&reference).enumerate() {
                    assert!(
                        allclose_slice(a.data(), b.data(), 1e-12, 1e-12),
                        "{} B={batch} channel {k}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn standard_pinn_architecture_order9() {
        // The paper's 3x24 network at the highest order it benchmarks.
        let mut rng = Prng::seeded(77);
        let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
        let x = Tensor::linspace(-1.0, 1.0, 4).reshape(&[4, 1]);
        let engine = NtpEngine::new(9);
        let ntp = engine.forward(&mlp, &x);
        assert_eq!(ntp.len(), 10);

        let mut g = Graph::new();
        let xn = g.input(x.shape());
        let pn = mlp.const_param_nodes(&mut g);
        let u = mlp.forward_graph(&mut g, xn, &pn);
        let stack = higher::derivative_stack(&mut g, u, xn, 9);
        let vals = g.eval(&[x], &stack);
        for order in 0..=9 {
            // Higher orders blow up in magnitude; compare relatively.
            assert!(
                allclose_slice(ntp[order].data(), vals.get(stack[order]).data(), 1e-7, 1e-8),
                "order {order}"
            );
        }
    }

    #[test]
    fn order_zero_matches_plain_forward_all_kinds() {
        let x = Tensor::linspace(-2.0, 2.0, 9).reshape(&[9, 1]);
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(21 + kind.index() as u64);
            let mlp = Mlp::uniform_with(1, 16, 2, 1, kind, &mut rng);
            let engine = NtpEngine::new(0);
            let channels = engine.forward(&mlp, &x);
            assert_eq!(channels.len(), 1);
            assert!(
                allclose_slice(channels[0].data(), mlp.forward(&x).data(), 1e-12, 1e-12),
                "{}",
                kind.name()
            );
        }
    }

    #[test]
    fn channels_shapes() {
        let mut rng = Prng::seeded(31);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let engine = NtpEngine::new(4);
        let x = Tensor::zeros(&[6, 1]);
        let channels = engine.forward(&mlp, &x);
        for c in &channels {
            assert_eq!(c.shape(), &[6, 1]);
        }
    }

    #[test]
    fn forward_n_truncates() {
        let mut rng = Prng::seeded(32);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let engine = NtpEngine::new(6);
        let x = Tensor::linspace(-1.0, 1.0, 3).reshape(&[3, 1]);
        let full = engine.forward(&mlp, &x);
        let trunc = engine.forward_n(&mlp, &x, 2);
        assert_eq!(trunc.len(), 3);
        for k in 0..=2 {
            assert!(allclose_slice(trunc[k].data(), full[k].data(), 1e-12, 1e-12));
        }
    }

    #[test]
    #[should_panic(expected = "exceeds engine")]
    fn n_bounds_checked() {
        let mut rng = Prng::seeded(33);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        NtpEngine::new(2).forward_n(&mlp, &Tensor::zeros(&[1, 1]), 3);
    }

    /// §Perf: the fused path's steady-state tensor allocations are
    /// exactly the `n+1` returned channels — per layer, zero heap
    /// allocation goes through the accounted constructors (towers,
    /// powers, combines and GEMM all live in the pooled scratch).
    #[test]
    fn fused_path_allocates_only_the_returned_channels() {
        let mut rng = Prng::seeded(44);
        let (width, depth, batch, n) = (16usize, 3usize, 64usize, 6usize);
        let mlp = Mlp::uniform(1, width, depth, 1, &mut rng);
        let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
        let engine = NtpEngine::new(n);
        let (cold_out, _cold) = alloc::measure(|| engine.forward(&mlp, &x));
        let (warm_out, warm) = alloc::measure(|| engine.forward(&mlp, &x));
        for (a, b) in cold_out.iter().zip(&warm_out) {
            assert_eq!(a, b, "scratch reuse changed results");
        }
        let outputs = ((n + 1) * batch * mlp.output_dim() * 8) as u64;
        assert_eq!(warm, outputs, "fused warm path allocated beyond its outputs");
        // The reference path still materializes towers/affine outputs per
        // layer — strictly more accounted bytes than the fused kernel.
        #[cfg(feature = "reference-oracle")]
        {
            let (_, ref_warm) = alloc::measure(|| engine.forward_reference(&mlp, &x, n));
            assert!(
                ref_warm > warm,
                "reference warm {ref_warm} should exceed fused warm {warm}"
            );
        }
    }

    #[test]
    fn repeated_calls_with_different_shapes_stay_correct() {
        // Scratch buffers are shape-checked; alternating batch sizes and
        // widths must not leak state between calls.
        let engine = NtpEngine::new(4);
        for (seed, width, batch) in [(1u64, 6usize, 3usize), (2, 10, 7), (3, 6, 3), (4, 4, 1)] {
            let mut rng = Prng::seeded(seed);
            let mlp = Mlp::uniform(1, width, 2, 1, &mut rng);
            let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
            let a = engine.forward(&mlp, &x);
            let fresh = NtpEngine::new(4);
            let b = fresh.forward(&mlp, &x);
            for (ta, tb) in a.iter().zip(&b) {
                assert_eq!(ta, tb, "scratch state leaked across calls");
            }
        }
    }

    /// The `Send`-but-not-`Sync` footgun is gone: the engine must be
    /// shareable by reference across threads (compile-time assertion).
    #[test]
    fn engine_is_send_and_sync() {
        fn assert_sync<T: Sync>() {}
        fn assert_send<T: Send>() {}
        assert_sync::<NtpEngine>();
        assert_send::<NtpEngine>();
        assert_sync::<ParallelPolicy>();
    }

    #[test]
    fn policy_worker_counts_clamp_sensibly() {
        assert_eq!(ParallelPolicy::Serial.workers_for(4096), 1);
        assert_eq!(ParallelPolicy::Fixed(4).workers_for(4096), 4);
        // Fixed counts clamp to the batch (and never hit zero).
        assert_eq!(ParallelPolicy::Fixed(8).workers_for(3), 3);
        assert_eq!(ParallelPolicy::Fixed(0).workers_for(16), 1);
        assert_eq!(ParallelPolicy::Fixed(4).workers_for(0), 1);
        // Auto stays serial on small batches regardless of core count.
        assert_eq!(ParallelPolicy::Auto.workers_for(8), 1);
        assert!(ParallelPolicy::Auto.workers_for(1 << 20) >= 1);
    }

    /// Chunked parallel execution is bitwise identical to serial — same
    /// per-row float ops, only the scheduling differs. Includes batches
    /// not divisible by the worker count (the off-by-one edge).
    #[test]
    fn parallel_forward_bitwise_matches_serial() {
        let mut rng = Prng::seeded(55);
        let mlp = Mlp::uniform(1, 10, 2, 1, &mut rng);
        let serial = NtpEngine::new(4);
        for batch in [1usize, 3, 5, 8, 17] {
            let x = Tensor::rand_uniform(&[batch, 1], -1.2, 1.2, &mut rng);
            let want = serial.forward(&mlp, &x);
            for threads in [2usize, 3, 4, 8] {
                let eng = NtpEngine::with_policy(4, ParallelPolicy::Fixed(threads));
                let got = eng.forward(&mlp, &x);
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(a, b, "B={batch} t={threads} channel {k}");
                }
            }
        }
    }

    /// A directional pass along `v = 1` in one input dimension *is*
    /// `d/dx` — and the directional seed performs the identical float
    /// ops (`x·w` then `+ b`; `1·w = w` exactly), so the jets are
    /// bitwise equal to the scalar path.
    #[test]
    fn directional_jet_reduces_to_scalar_forward_in_1d() {
        let mut rng = Prng::seeded(91);
        for kind in ActivationKind::ALL {
            let mlp = Mlp::uniform_with(1, 10, 2, 1, kind, &mut rng);
            let x = Tensor::rand_uniform(&[9, 1], -1.2, 1.2, &mut rng);
            let v = Tensor::ones(&[9, 1]);
            let engine = NtpEngine::new(4);
            let scalar = engine.forward_n(&mlp, &x, 4);
            let dir = engine.forward_directional(&mlp, &x, &v, 4);
            for (k, (a, b)) in scalar.iter().zip(&dir).enumerate() {
                assert_eq!(a, b, "{} channel {k}", kind.name());
            }
        }
    }

    /// Directional jets against the nested-tape directional stack — the
    /// in-crate differential smoke (the multivariate property sweep and
    /// the mixed-partial assembly live in
    /// `rust/tests/operator_exactness.rs`).
    #[test]
    fn directional_jet_matches_nested_tape() {
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(0xD12 + kind.index() as u64);
            let mlp = Mlp::uniform_with(2, 8, 2, 1, kind, &mut rng);
            let x = Tensor::rand_uniform(&[6, 2], -1.0, 1.0, &mut rng);
            let v = Tensor::rand_uniform(&[6, 2], -1.0, 1.0, &mut rng);
            let n = 3;
            let engine = NtpEngine::new(n);
            let jet = engine.forward_directional(&mlp, &x, &v, n);

            let mut g = Graph::new();
            let xn = g.input(x.shape());
            let pn = mlp.const_param_nodes(&mut g);
            let u = mlp.forward_graph(&mut g, xn, &pn);
            let stack = higher::directional_stack(&mut g, u, xn, &v, n);
            let vals = g.eval(&[x.clone()], &stack);
            for order in 0..=n {
                assert!(
                    allclose_slice(
                        jet[order].data(),
                        vals.get(stack[order]).data(),
                        1e-9,
                        1e-10
                    ),
                    "{} order {order}",
                    kind.name()
                );
            }
        }
    }

    /// Chunked directional execution is bitwise identical to serial,
    /// including row counts not divisible by the worker count.
    #[test]
    fn directional_parallel_bitwise_matches_serial() {
        let mut rng = Prng::seeded(92);
        let mlp = Mlp::uniform(3, 10, 2, 1, &mut rng);
        let serial = NtpEngine::new(3);
        for batch in [1usize, 5, 17] {
            let x = Tensor::rand_uniform(&[batch, 3], -1.0, 1.0, &mut rng);
            let v = Tensor::rand_uniform(&[batch, 3], -1.0, 1.0, &mut rng);
            let want = serial.forward_directional(&mlp, &x, &v, 3);
            for threads in [2usize, 3, 8] {
                let eng = NtpEngine::with_policy(3, ParallelPolicy::Fixed(threads));
                let got = eng.forward_directional(&mlp, &x, &v, 3);
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(a, b, "B={batch} t={threads} channel {k}");
                }
            }
        }
    }

    /// One engine shared by reference across threads: concurrent
    /// `forward` calls must not corrupt each other's scratch.
    #[test]
    fn shared_engine_is_safe_under_concurrent_forward() {
        let mut rng = Prng::seeded(56);
        let mlp = Mlp::uniform(1, 12, 2, 1, &mut rng);
        let engine = NtpEngine::with_policy(3, ParallelPolicy::Fixed(2));
        let xs: Vec<Tensor> = (0..8)
            .map(|i| Tensor::rand_uniform(&[5 + i, 1], -1.0, 1.0, &mut rng))
            .collect();
        let baseline: Vec<Vec<Tensor>> = xs
            .iter()
            .map(|x| NtpEngine::new(3).forward(&mlp, x))
            .collect();
        let results: Vec<Vec<Tensor>> = std::thread::scope(|s| {
            let engine = &engine;
            let mlp = &mlp;
            let handles: Vec<_> = xs
                .iter()
                .map(|x| s.spawn(move || engine.forward(mlp, x)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, (want, got)) in baseline.iter().zip(&results).enumerate() {
            for (k, (a, b)) in want.iter().zip(got).enumerate() {
                assert_eq!(a, b, "caller {i} channel {k}");
            }
        }
    }

    #[test]
    fn op_count_is_quasilinear_not_exponential() {
        let engine = NtpEngine::new(12);
        let ops: Vec<usize> = (1..=12).map(|n| engine.op_count(n, 3)).collect();
        // Growth ratio should shrink toward 1 (subexponential), unlike the
        // autodiff graph whose growth ratio stays >= some c > 1.
        let r_early = ops[3] as f64 / ops[2] as f64;
        let r_late = ops[11] as f64 / ops[10] as f64;
        assert!(r_late < r_early, "{ops:?}");
        assert!(r_late < 1.6, "late growth ratio {r_late}");
    }
}
