//! The n-TangentProp forward pass (Algorithm 1 of the paper), pure tensor
//! version — the inference/benchmark hot path.
//!
//! Channel state per layer: `y[i] = d^i z^ℓ / dx^i`, shape `[B, width]`.
//! Crossing an activation applies Faà di Bruno (eq. 5b) using the
//! activation's derivative tower; crossing the affine layer is linear in
//! every channel (eq. 5a), with the bias entering channel 0 only.

use super::activation::{SmoothActivation, Tanh};
use super::bell::FaaDiBruno;
use crate::nn::Mlp;
use crate::tensor::Tensor;

/// Engine with precomputed Faà di Bruno + activation-tower tables for up
/// to `n_max` derivatives.
pub struct NtpEngine {
    n_max: usize,
    fdb: FaaDiBruno,
    act: Tanh,
}

impl NtpEngine {
    /// Build tables for up to `n_max` derivatives.
    pub fn new(n_max: usize) -> NtpEngine {
        NtpEngine {
            n_max,
            fdb: FaaDiBruno::new(n_max),
            act: Tanh::new(n_max),
        }
    }

    pub fn n_max(&self) -> usize {
        self.n_max
    }

    pub fn tables(&self) -> &FaaDiBruno {
        &self.fdb
    }

    pub fn activation(&self) -> &Tanh {
        &self.act
    }

    /// Compute `[u, u', ..., u^(n_max)]` for `x: [B, 1]`.
    pub fn forward(&self, mlp: &Mlp, x: &Tensor) -> Vec<Tensor> {
        self.forward_n(mlp, x, self.n_max)
    }

    /// Compute `[u, u', ..., u^(n)]` for `n <= n_max`.
    ///
    /// Single forward pass; all channels advance together (the paper's
    /// headline algorithm).
    pub fn forward_n(&self, mlp: &Mlp, x: &Tensor, n: usize) -> Vec<Tensor> {
        assert!(n <= self.n_max, "n={n} exceeds engine n_max={}", self.n_max);
        assert_eq!(x.rank(), 2, "x must be [B, 1]");
        assert_eq!(x.shape()[1], 1, "n-TangentProp propagates d/dx of a scalar input");
        assert_eq!(mlp.input_dim(), 1, "network input dim must be 1");
        let batch = x.shape()[0];

        // First affine layer seeds the channels:
        //   y0 = x W^T + b, y1 = 1 W^T (d x/dx = 1), y_i = 0 for i >= 2.
        let l0 = &mlp.layers[0];
        let mut y: Vec<Tensor> = Vec::with_capacity(n + 1);
        y.push(l0.apply(x));
        if n >= 1 {
            y.push(Tensor::ones(&[batch, 1]).matmul_nt(&l0.w));
        }
        for _ in 2..=n {
            y.push(Tensor::zeros(y[0].shape()));
        }

        for layer in &mlp.layers[1..] {
            // Activation tower σ^(s)(y0), s = 0..=n, one tanh per element.
            let towers = self.act.tower(&y[0], n);
            // §Perf: precompute the channel powers y_j^c every partition
            // term needs (c ≤ n/j), once per layer, so the combine loops
            // are pure fused multiply-adds with no powi in the hot loop.
            // All ξ_i consume *pre-update* channels (j ≤ i is untouched
            // by the downward loop), so one snapshot is valid throughout.
            let powers = self.channel_powers(&y, n);
            // Faà di Bruno combine, channels high-to-low so y_j (j < i)
            // stay untouched while computing ξ_i.
            for i in (1..=n).rev() {
                y[i] = self.combine_channel(i, &towers, &powers);
            }
            // Affine layer: channel 0 gets the bias, others are linear.
            let h0 = layer.apply(&towers[0]);
            for item in y.iter_mut().skip(1) {
                *item = layer.apply_linear(item);
            }
            y[0] = h0;
        }
        y
    }

    /// `powers[j][c-1] = y_j^c` for every multiplicity any partition term
    /// of order ≤ n can request (`c ≤ n/j`), built incrementally.
    fn channel_powers(&self, y: &[Tensor], n: usize) -> Vec<Vec<Tensor>> {
        let mut powers: Vec<Vec<Tensor>> = Vec::with_capacity(n + 1);
        powers.push(Vec::new()); // j = 0 unused
        for (j, yj) in y.iter().enumerate().skip(1) {
            let c_max = if j <= n { n / j } else { 0 };
            let mut row = Vec::with_capacity(c_max);
            if c_max >= 1 {
                row.push(yj.clone());
                for _ in 2..=c_max {
                    let next = row.last().unwrap().mul(yj);
                    row.push(next);
                }
            }
            powers.push(row);
        }
        powers
    }

    /// ξ_i = Σ_{p∈P(i)} C_p σ^{(|p|)}(y0) Π_j y_j^{p_j}   (eq. 5b)
    ///
    /// §Perf: fused per-element accumulation over precomputed powers —
    /// one output buffer, no temporaries or `powi` per term (the naive
    /// version churned ~15 MB of temporaries per layer at n = 9).
    fn combine_channel(&self, i: usize, towers: &[Tensor], powers: &[Vec<Tensor>]) -> Tensor {
        let len = towers[0].numel();
        let mut z = Tensor::zeros(towers[0].shape());
        let zd = z.data_mut();
        for term in self.fdb.terms(i) {
            let tower = towers[term.outer_order].data();
            let coeff = term.coeff;
            match term.factors.as_slice() {
                [(j, c)] => {
                    let a = powers[*j][*c - 1].data();
                    for e in 0..len {
                        zd[e] += coeff * tower[e] * a[e];
                    }
                }
                [(j1, c1), (j2, c2)] => {
                    let a = powers[*j1][*c1 - 1].data();
                    let b = powers[*j2][*c2 - 1].data();
                    for e in 0..len {
                        zd[e] += coeff * tower[e] * a[e] * b[e];
                    }
                }
                factors => {
                    let slices: Vec<&[f64]> = factors
                        .iter()
                        .map(|&(j, c)| powers[j][c - 1].data())
                        .collect();
                    for e in 0..len {
                        let mut prod = coeff * tower[e];
                        for s in &slices {
                            prod *= s[e];
                        }
                        zd[e] += prod;
                    }
                }
            }
        }
        z
    }

    /// Number of *tensor ops* the forward pass executes for order `n` and
    /// `depth` hidden layers — the quasilinear `O(n·p(n)·L)` work factor
    /// the benchmark reports annotate.
    pub fn op_count(&self, n: usize, depth: usize) -> usize {
        let combine: usize = (1..=n)
            .map(|i| {
                self.fdb
                    .terms(i)
                    .iter()
                    .map(|t| 1 + t.factors.len())
                    .sum::<usize>()
            })
            .sum();
        depth * (combine + (n + 1) /* tower + matmuls */ + (n + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{higher, Graph};
    use crate::util::prng::Prng;
    use crate::util::{allclose_slice, ptest};

    /// The paper's central claim, as a property: n-TangentProp equals the
    /// repeated-autodiff derivative stack *exactly* (both are exact
    /// methods), across random architectures and batches.
    #[test]
    fn matches_repeated_autodiff_exactly() {
        ptest::check(
            ptest::Config { cases: 20, seed: 0x5EED },
            |rng: &mut Prng| {
                let width = 2 + rng.below(12) as usize;
                let depth = 1 + rng.below(3) as usize;
                let batch = 1 + rng.below(5) as usize;
                let n = 1 + rng.below(5) as usize;
                let mlp = Mlp::uniform(1, width, depth, 1, rng);
                let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, rng);
                (mlp, x, n)
            },
            |(mlp, x, n)| {
                let engine = NtpEngine::new(*n);
                let ntp = engine.forward(mlp, x);

                let mut g = Graph::new();
                let xn = g.input(x.shape());
                let pn = mlp.const_param_nodes(&mut g);
                let u = mlp.forward_graph(&mut g, xn, &pn);
                let stack = higher::derivative_stack(&mut g, u, xn, *n);
                let vals = g.eval(&[x.clone()], &stack);

                for order in 0..=*n {
                    let a = ntp[order].data();
                    let b = vals.get(stack[order]).data();
                    if !allclose_slice(a, b, 1e-9, 1e-9) {
                        return Err(format!(
                            "order {order}: ntp {:?} vs autodiff {:?}",
                            &a[..a.len().min(4)],
                            &b[..b.len().min(4)]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn standard_pinn_architecture_order9() {
        // The paper's 3x24 network at the highest order it benchmarks.
        let mut rng = Prng::seeded(77);
        let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
        let x = Tensor::linspace(-1.0, 1.0, 4).reshape(&[4, 1]);
        let engine = NtpEngine::new(9);
        let ntp = engine.forward(&mlp, &x);
        assert_eq!(ntp.len(), 10);

        let mut g = Graph::new();
        let xn = g.input(x.shape());
        let pn = mlp.const_param_nodes(&mut g);
        let u = mlp.forward_graph(&mut g, xn, &pn);
        let stack = higher::derivative_stack(&mut g, u, xn, 9);
        let vals = g.eval(&[x], &stack);
        for order in 0..=9 {
            // Higher orders blow up in magnitude; compare relatively.
            assert!(
                allclose_slice(ntp[order].data(), vals.get(stack[order]).data(), 1e-7, 1e-8),
                "order {order}"
            );
        }
    }

    #[test]
    fn order_zero_matches_plain_forward() {
        let mut rng = Prng::seeded(21);
        let mlp = Mlp::uniform(1, 16, 2, 1, &mut rng);
        let x = Tensor::linspace(-2.0, 2.0, 9).reshape(&[9, 1]);
        let engine = NtpEngine::new(0);
        let channels = engine.forward(&mlp, &x);
        assert_eq!(channels.len(), 1);
        assert!(allclose_slice(
            channels[0].data(),
            mlp.forward(&x).data(),
            1e-14,
            1e-14
        ));
    }

    #[test]
    fn channels_shapes() {
        let mut rng = Prng::seeded(31);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let engine = NtpEngine::new(4);
        let x = Tensor::zeros(&[6, 1]);
        let channels = engine.forward(&mlp, &x);
        for c in &channels {
            assert_eq!(c.shape(), &[6, 1]);
        }
    }

    #[test]
    fn forward_n_truncates() {
        let mut rng = Prng::seeded(32);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let engine = NtpEngine::new(6);
        let x = Tensor::linspace(-1.0, 1.0, 3).reshape(&[3, 1]);
        let full = engine.forward(&mlp, &x);
        let trunc = engine.forward_n(&mlp, &x, 2);
        assert_eq!(trunc.len(), 3);
        for k in 0..=2 {
            assert!(allclose_slice(trunc[k].data(), full[k].data(), 1e-12, 1e-12));
        }
    }

    #[test]
    #[should_panic(expected = "exceeds engine")]
    fn n_bounds_checked() {
        let mut rng = Prng::seeded(33);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        NtpEngine::new(2).forward_n(&mlp, &Tensor::zeros(&[1, 1]), 3);
    }

    #[test]
    fn op_count_is_quasilinear_not_exponential() {
        let engine = NtpEngine::new(12);
        let ops: Vec<usize> = (1..=12).map(|n| engine.op_count(n, 3)).collect();
        // Growth ratio should shrink toward 1 (subexponential), unlike the
        // autodiff graph whose growth ratio stays >= some c > 1.
        let r_early = ops[3] as f64 / ops[2] as f64;
        let r_late = ops[11] as f64 / ops[10] as f64;
        assert!(r_late < r_early, "{ops:?}");
        assert!(r_late < 1.6, "late growth ratio {r_late}");
    }
}
