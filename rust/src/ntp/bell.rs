//! Faà di Bruno coefficients (partial Bell polynomial coefficients of the
//! second kind) — the constants `C_p` of eq. (4)/(5b).
//!
//! For a partition `p` of `n`,
//! `C_p = n! / ( Π_j p_j! · (j!)^{p_j} )`.
//! The paper recommends precomputing and caching these tables; that is
//! exactly what [`FaaDiBruno`] does (once per engine, up to `n_max`).

use super::partitions::{partitions, Partition};
#[cfg(test)]
use super::partitions::partition_count;

/// One term of the Faà di Bruno sum for a fixed derivative order.
#[derive(Clone, Debug)]
pub struct Term {
    /// Integer coefficient `C_p` (exact in u128, exposed as f64).
    pub coeff: f64,
    /// `|p|` — which derivative of the outer function this term multiplies.
    pub outer_order: usize,
    /// Non-zero `(j, p_j)` pairs: the product `Π_j (g^{(j)})^{p_j}`.
    pub factors: Vec<(usize, usize)>,
}

/// Precomputed Faà di Bruno tables for derivative orders `1..=n_max`.
#[derive(Clone, Debug)]
pub struct FaaDiBruno {
    /// Highest tabulated order.
    pub n_max: usize,
    /// `terms[i]` holds the sum for derivative order `i` (index 0 unused).
    terms: Vec<Vec<Term>>,
}

fn factorial_u128(n: usize) -> u128 {
    (1..=n as u128).product()
}

/// Exact `C_p` as u128 (panics on overflow — fine for n ≤ 25).
fn coeff_u128(p: &Partition) -> u128 {
    let mut denom: u128 = 1;
    for &(j, c) in &p.parts {
        denom = denom
            .checked_mul(factorial_u128(c))
            .and_then(|d| d.checked_mul(factorial_u128(j).checked_pow(c as u32).unwrap()))
            .expect("Faà di Bruno coefficient overflow");
    }
    factorial_u128(p.n) / denom
}

impl FaaDiBruno {
    /// Build tables up to `n_max` derivatives.
    pub fn new(n_max: usize) -> FaaDiBruno {
        let mut terms = vec![Vec::new()];
        for n in 1..=n_max {
            let mut row = Vec::new();
            for p in partitions(n) {
                row.push(Term {
                    coeff: coeff_u128(&p) as f64,
                    outer_order: p.order(),
                    factors: p.parts.clone(),
                });
            }
            terms.push(row);
        }
        FaaDiBruno { n_max, terms }
    }

    /// Terms of the order-`n` Faà di Bruno sum.
    pub fn terms(&self, n: usize) -> &[Term] {
        assert!(n >= 1 && n <= self.n_max, "order {n} outside table (n_max={})", self.n_max);
        &self.terms[n]
    }

    /// Total number of table terms `Σ_{i<=n} p(i)` — the per-layer work
    /// factor of the quasilinear bound.
    pub fn total_terms(&self, n: usize) -> usize {
        (1..=n).map(|i| self.terms[i].len()).sum()
    }

    /// Evaluate `d^n/dx^n f(g(x))` for scalar towers:
    /// `f_derivs[k] = f^{(k)}(g(x))` (k = 0..=n) and
    /// `g_derivs[j] = g^{(j)}(x)` (j = 0..=n).
    ///
    /// The reference implementation of the formula; the tensor/tape
    /// variants in [`crate::ntp::forward`] and [`crate::ntp::tape`] must
    /// agree with this exactly, and the scalar form is also what the
    /// ground-truth Burgers solver uses.
    pub fn compose_scalar(&self, n: usize, f_derivs: &[f64], g_derivs: &[f64]) -> f64 {
        assert!(f_derivs.len() > n && g_derivs.len() > n);
        if n == 0 {
            return f_derivs[0];
        }
        let mut acc = 0.0;
        for term in self.terms(n) {
            let mut prod = term.coeff * f_derivs[term.outer_order];
            for &(j, c) in &term.factors {
                prod *= g_derivs[j].powi(c as i32);
            }
            acc += prod;
        }
        acc
    }
}

// ------------------------------------------------------ compiled programs

/// One instruction of a compiled Faà di Bruno program: accumulate
/// `coeff · σ^{(tower)}(y₀) · Π factors` into its order's output channel.
///
/// The factor operands are pre-resolved *plane ids* (see [`FdbProgram`]),
/// so the fused kernel executes the term with plain indexed loads — no
/// partition walking, no `(j, c)` decoding, no allocation.
#[derive(Clone, Copy, Debug)]
pub struct FdbOp {
    /// Integer coefficient `C_p`, exact as f64.
    pub coeff: f64,
    /// Tower plane index `|p|` — which σ derivative this term multiplies.
    pub tower: u32,
    /// Start of this op's operand ids in [`FdbProgram::factor_ids`].
    pub fstart: u32,
    /// Number of operand ids (≥ 1 for every partition term).
    pub flen: u32,
}

/// A power-plane fill `dst = a · b` (elementwise over a tile): builds
/// `y_j^c` as `y_j^{c-1} · y_j`. All three fields are operand plane ids.
#[derive(Clone, Copy, Debug)]
pub struct PowFill {
    /// Destination plane (always a power plane, id > both sources).
    pub dst: u32,
    /// Left source plane (`y_j^{c-1}`: the channel itself when c = 2).
    pub a: u32,
    /// Right source plane (the channel `y_j`).
    pub b: u32,
}

/// The [`FaaDiBruno`] term tables compiled into a flat, allocation-free
/// instruction program — what the fused element-tiled kernel in
/// [`crate::ntp::forward`] interprets.
///
/// Operand *plane ids* index a contiguous tile workspace: ids
/// `0..=n_max` are the derivative channels `y_j`, ids `n_max+1..` are
/// power planes `y_j^c` (c ≥ 2) in first-use order. Because orders are
/// compiled in ascending order, the fills needed for all terms of order
/// ≤ n form a *prefix* of [`FdbProgram::fills`], so a truncated
/// `forward_n` executes exactly the fills it needs.
#[derive(Clone, Debug)]
pub struct FdbProgram {
    n_max: usize,
    n_operands: usize,
    fills: Vec<PowFill>,
    /// `fill_counts[i]` = fills required by all orders ≤ i (prefix lengths).
    fill_counts: Vec<usize>,
    ops: Vec<FdbOp>,
    /// `op_ranges[i]` = the `ops` range holding order `i`'s terms.
    op_ranges: Vec<(usize, usize)>,
    factor_ids: Vec<u32>,
}

impl FdbProgram {
    /// Compile the term tables into the flat program (once per engine).
    pub fn compile(fdb: &FaaDiBruno) -> FdbProgram {
        let n_max = fdb.n_max;
        // slots[j][c-2] = operand id of y_j^c (c >= 2), grown on demand.
        let mut slots: Vec<Vec<u32>> = vec![Vec::new(); n_max + 1];
        let mut n_operands = n_max + 1;
        let mut fills = Vec::new();
        let mut fill_counts = vec![0usize; n_max + 1];
        let mut ops = Vec::new();
        let mut op_ranges = vec![(0usize, 0usize); n_max + 1];
        let mut factor_ids: Vec<u32> = Vec::new();
        for i in 1..=n_max {
            let start = ops.len();
            for term in fdb.terms(i) {
                let fstart = factor_ids.len();
                for &(j, c) in &term.factors {
                    // Materialize the missing powers y_j^2 ..= y_j^c.
                    while slots[j].len() + 1 < c {
                        let cc = slots[j].len() + 2; // next missing multiplicity
                        let a = if cc == 2 { j as u32 } else { slots[j][cc - 3] };
                        let dst = n_operands as u32;
                        n_operands += 1;
                        fills.push(PowFill { dst, a, b: j as u32 });
                        slots[j].push(dst);
                    }
                    factor_ids.push(if c == 1 { j as u32 } else { slots[j][c - 2] });
                }
                ops.push(FdbOp {
                    coeff: term.coeff,
                    tower: term.outer_order as u32,
                    fstart: fstart as u32,
                    flen: (factor_ids.len() - fstart) as u32,
                });
            }
            op_ranges[i] = (start, ops.len());
            fill_counts[i] = fills.len();
        }
        FdbProgram { n_max, n_operands, fills, fill_counts, ops, op_ranges, factor_ids }
    }

    /// Highest compiled order.
    pub fn n_max(&self) -> usize {
        self.n_max
    }

    /// Total operand planes: `n_max + 1` channels plus every power plane.
    pub fn n_operands(&self) -> usize {
        self.n_operands
    }

    /// The power fills required by all orders ≤ `n`, in execution order
    /// (every fill's sources precede its destination).
    pub fn fills(&self, n: usize) -> &[PowFill] {
        assert!(n <= self.n_max, "order {n} outside program (n_max={})", self.n_max);
        &self.fills[..self.fill_counts[n]]
    }

    /// The compiled terms of order `n` (1 ≤ n ≤ n_max).
    pub fn ops(&self, n: usize) -> &[FdbOp] {
        assert!(
            n >= 1 && n <= self.n_max,
            "order {n} outside program (n_max={})",
            self.n_max
        );
        let (lo, hi) = self.op_ranges[n];
        &self.ops[lo..hi]
    }

    /// An op's operand plane ids.
    pub fn factor_ids(&self, op: &FdbOp) -> &[u32] {
        &self.factor_ids[op.fstart as usize..(op.fstart + op.flen) as usize]
    }
}

/// Bell numbers B_n (OEIS A000110) — the value of the complete Bell
/// polynomial at all-ones, used as a table sanity invariant:
/// `Σ_p C_p = B_n`.
pub fn bell_number(n: usize) -> u128 {
    // Bell triangle.
    let mut row = vec![1u128];
    for _ in 0..n {
        let mut next = Vec::with_capacity(row.len() + 1);
        next.push(*row.last().unwrap());
        for v in &row {
            let last = *next.last().unwrap();
            next.push(last + v);
        }
        row = next;
    }
    row[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coefficients_sum_to_bell_numbers() {
        // Σ_{p ∈ P(n)} C_p = B_n: 1, 2, 5, 15, 52, 203, 877, 4140, ...
        let fdb = FaaDiBruno::new(12);
        for n in 1..=12 {
            let total: f64 = fdb.terms(n).iter().map(|t| t.coeff).sum();
            assert_eq!(total as u128, bell_number(n), "n={n}");
        }
    }

    #[test]
    fn order3_terms_are_the_textbook_ones() {
        // (f∘g)''' = f'''·(g')³ + 3 f''·g'·g'' + f'·g'''
        let fdb = FaaDiBruno::new(3);
        let terms = fdb.terms(3);
        assert_eq!(terms.len(), 3);
        let find = |outer: usize| terms.iter().find(|t| t.outer_order == outer).unwrap();
        assert_eq!(find(3).coeff, 1.0);
        assert_eq!(find(3).factors, vec![(1, 3)]);
        assert_eq!(find(2).coeff, 3.0);
        assert_eq!(find(2).factors, vec![(1, 1), (2, 1)]);
        assert_eq!(find(1).coeff, 1.0);
        assert_eq!(find(1).factors, vec![(3, 1)]);
    }

    #[test]
    fn order4_coefficients() {
        // (f∘g)'''' : 1·f''''(g')⁴ + 6·f'''(g')²g'' + 3·f''(g'')² + 4·f''g'g''' + 1·f'g''''
        let fdb = FaaDiBruno::new(4);
        let mut coeffs: Vec<f64> = fdb.terms(4).iter().map(|t| t.coeff).collect();
        coeffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(coeffs, vec![1.0, 1.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn compose_scalar_chain_rule_order1() {
        let fdb = FaaDiBruno::new(4);
        // f(g) with f'(g)=2, g'(x)=3 → (f∘g)' = 6
        let f = [0.0, 2.0, 0.0, 0.0, 0.0];
        let g = [0.0, 3.0, 0.0, 0.0, 0.0];
        assert_eq!(fdb.compose_scalar(1, &f, &g), 6.0);
    }

    #[test]
    fn compose_scalar_matches_analytic_example() {
        // h(x) = exp(sin x): h^{(n)} computable since f=exp has all derivs
        // equal to exp(g), g=sin has the rotating tower.
        let fdb = FaaDiBruno::new(6);
        let x: f64 = 0.7;
        let e = x.sin().exp();
        let f: Vec<f64> = (0..=6).map(|_| e).collect();
        let g: Vec<f64> = (0..=6)
            .map(|k| match k % 4 {
                0 => x.sin(),
                1 => x.cos(),
                2 => -x.sin(),
                _ => -x.cos(),
            })
            .collect();
        // Analytic derivatives of exp(sin x) at x (via symbolic expansion):
        let s = x.sin();
        let c = x.cos();
        let h1 = e * c;
        let h2 = e * (c * c - s);
        let h3 = e * (c * c * c - 3.0 * s * c - c);
        let h4 = e * (c.powi(4) - 6.0 * s * c * c - 4.0 * c * c + 3.0 * s * s + s);
        for (n, expect) in [(1, h1), (2, h2), (3, h3), (4, h4)] {
            let got = fdb.compose_scalar(n, &f, &g);
            assert!(
                (got - expect).abs() < 1e-10 * expect.abs().max(1.0),
                "n={n}: {got} vs {expect}"
            );
        }
    }

    #[test]
    fn term_counts_follow_partition_function() {
        let fdb = FaaDiBruno::new(10);
        for n in 1..=10 {
            assert_eq!(fdb.terms(n).len() as u64, partition_count(n));
        }
        assert_eq!(fdb.total_terms(3), (1 + 2 + 3) as usize);
    }

    #[test]
    fn bell_numbers_oeis() {
        let expect: [u128; 9] = [1, 1, 2, 5, 15, 52, 203, 877, 4140];
        for (n, &e) in expect.iter().enumerate() {
            assert_eq!(bell_number(n), e, "B_{n}");
        }
    }

    #[test]
    #[should_panic(expected = "outside table")]
    fn out_of_range_order_panics() {
        FaaDiBruno::new(3).terms(4);
    }

    /// Interpret a compiled program on scalar "planes" (one element per
    /// plane) — an independent executor of the instruction format.
    fn run_program_scalar(prog: &FdbProgram, n: usize, f: &[f64], g: &[f64]) -> Vec<f64> {
        let mut planes = vec![0.0; prog.n_operands()];
        planes[..=prog.n_max()].copy_from_slice(&g[..=prog.n_max()]);
        for fill in prog.fills(n) {
            planes[fill.dst as usize] = planes[fill.a as usize] * planes[fill.b as usize];
        }
        let mut out = vec![f[0]];
        for i in 1..=n {
            let mut acc = 0.0;
            for op in prog.ops(i) {
                let mut prod = op.coeff * f[op.tower as usize];
                for &fid in prog.factor_ids(op) {
                    prod *= planes[fid as usize];
                }
                acc += prod;
            }
            out.push(acc);
        }
        out
    }

    /// The compiled program computes the same composition derivatives as
    /// the reference `compose_scalar`, at full and truncated orders.
    #[test]
    fn compiled_program_matches_compose_scalar() {
        let fdb = FaaDiBruno::new(8);
        let prog = FdbProgram::compile(&fdb);
        // exp(sin x): f derivatives all e^{sin x}, g the sine tower.
        let x: f64 = 0.45;
        let e = x.sin().exp();
        let f: Vec<f64> = (0..=8).map(|_| e).collect();
        let g: Vec<f64> = (0..=8)
            .map(|k| (x + k as f64 * std::f64::consts::FRAC_PI_2).sin())
            .collect();
        for n in [0usize, 1, 3, 5, 8] {
            let got = run_program_scalar(&prog, n, &f, &g);
            assert_eq!(got.len(), n + 1);
            for (i, &v) in got.iter().enumerate() {
                let want = fdb.compose_scalar(i, &f, &g);
                assert!(
                    (v - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "n={n} order {i}: {v} vs {want}"
                );
            }
        }
    }

    /// Structural invariants of the compiled format: one op per partition
    /// term, the exact power-slot count, fill-prefix monotonicity, and
    /// every fill's sources preceding its destination.
    #[test]
    fn compiled_program_structure() {
        let n_max = 9;
        let fdb = FaaDiBruno::new(n_max);
        let prog = FdbProgram::compile(&fdb);
        assert_eq!(prog.n_max(), n_max);
        for i in 1..=n_max {
            assert_eq!(prog.ops(i).len(), fdb.terms(i).len(), "order {i}");
        }
        // Power slots: y_j^c for 2 <= c <= n_max/j, nothing else.
        let expect_slots: usize = (1..=n_max)
            .map(|j| (n_max / j).saturating_sub(1))
            .sum();
        assert_eq!(prog.n_operands(), n_max + 1 + expect_slots);
        assert_eq!(prog.fills(n_max).len(), expect_slots);
        let mut prev = 0;
        for n in 0..=n_max {
            let cnt = prog.fills(n).len();
            assert!(cnt >= prev, "fill prefix shrank at order {n}");
            prev = cnt;
        }
        for fill in prog.fills(n_max) {
            assert!(fill.a < fill.dst && fill.b < fill.dst, "fill ordering");
            assert!((fill.b as usize) <= n_max, "fill rhs must be a channel");
        }
    }
}
