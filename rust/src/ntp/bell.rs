//! Faà di Bruno coefficients (partial Bell polynomial coefficients of the
//! second kind) — the constants `C_p` of eq. (4)/(5b).
//!
//! For a partition `p` of `n`,
//! `C_p = n! / ( Π_j p_j! · (j!)^{p_j} )`.
//! The paper recommends precomputing and caching these tables; that is
//! exactly what [`FaaDiBruno`] does (once per engine, up to `n_max`).

use super::partitions::{partitions, Partition};
#[cfg(test)]
use super::partitions::partition_count;

/// One term of the Faà di Bruno sum for a fixed derivative order.
#[derive(Clone, Debug)]
pub struct Term {
    /// Integer coefficient `C_p` (exact in u128, exposed as f64).
    pub coeff: f64,
    /// `|p|` — which derivative of the outer function this term multiplies.
    pub outer_order: usize,
    /// Non-zero `(j, p_j)` pairs: the product `Π_j (g^{(j)})^{p_j}`.
    pub factors: Vec<(usize, usize)>,
}

/// Precomputed Faà di Bruno tables for derivative orders `1..=n_max`.
#[derive(Clone, Debug)]
pub struct FaaDiBruno {
    /// Highest tabulated order.
    pub n_max: usize,
    /// `terms[i]` holds the sum for derivative order `i` (index 0 unused).
    terms: Vec<Vec<Term>>,
}

fn factorial_u128(n: usize) -> u128 {
    (1..=n as u128).product()
}

/// Exact `C_p` as u128 (panics on overflow — fine for n ≤ 25).
fn coeff_u128(p: &Partition) -> u128 {
    let mut denom: u128 = 1;
    for &(j, c) in &p.parts {
        denom = denom
            .checked_mul(factorial_u128(c))
            .and_then(|d| d.checked_mul(factorial_u128(j).checked_pow(c as u32).unwrap()))
            .expect("Faà di Bruno coefficient overflow");
    }
    factorial_u128(p.n) / denom
}

impl FaaDiBruno {
    /// Build tables up to `n_max` derivatives.
    pub fn new(n_max: usize) -> FaaDiBruno {
        let mut terms = vec![Vec::new()];
        for n in 1..=n_max {
            let mut row = Vec::new();
            for p in partitions(n) {
                row.push(Term {
                    coeff: coeff_u128(&p) as f64,
                    outer_order: p.order(),
                    factors: p.parts.clone(),
                });
            }
            terms.push(row);
        }
        FaaDiBruno { n_max, terms }
    }

    /// Terms of the order-`n` Faà di Bruno sum.
    pub fn terms(&self, n: usize) -> &[Term] {
        assert!(n >= 1 && n <= self.n_max, "order {n} outside table (n_max={})", self.n_max);
        &self.terms[n]
    }

    /// Total number of table terms `Σ_{i<=n} p(i)` — the per-layer work
    /// factor of the quasilinear bound.
    pub fn total_terms(&self, n: usize) -> usize {
        (1..=n).map(|i| self.terms[i].len()).sum()
    }

    /// Evaluate `d^n/dx^n f(g(x))` for scalar towers:
    /// `f_derivs[k] = f^{(k)}(g(x))` (k = 0..=n) and
    /// `g_derivs[j] = g^{(j)}(x)` (j = 0..=n).
    ///
    /// The reference implementation of the formula; the tensor/tape
    /// variants in [`crate::ntp::forward`] and [`crate::ntp::tape`] must
    /// agree with this exactly, and the scalar form is also what the
    /// ground-truth Burgers solver uses.
    pub fn compose_scalar(&self, n: usize, f_derivs: &[f64], g_derivs: &[f64]) -> f64 {
        assert!(f_derivs.len() > n && g_derivs.len() > n);
        if n == 0 {
            return f_derivs[0];
        }
        let mut acc = 0.0;
        for term in self.terms(n) {
            let mut prod = term.coeff * f_derivs[term.outer_order];
            for &(j, c) in &term.factors {
                prod *= g_derivs[j].powi(c as i32);
            }
            acc += prod;
        }
        acc
    }
}

/// Bell numbers B_n (OEIS A000110) — the value of the complete Bell
/// polynomial at all-ones, used as a table sanity invariant:
/// `Σ_p C_p = B_n`.
pub fn bell_number(n: usize) -> u128 {
    // Bell triangle.
    let mut row = vec![1u128];
    for _ in 0..n {
        let mut next = Vec::with_capacity(row.len() + 1);
        next.push(*row.last().unwrap());
        for v in &row {
            let last = *next.last().unwrap();
            next.push(last + v);
        }
        row = next;
    }
    row[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coefficients_sum_to_bell_numbers() {
        // Σ_{p ∈ P(n)} C_p = B_n: 1, 2, 5, 15, 52, 203, 877, 4140, ...
        let fdb = FaaDiBruno::new(12);
        for n in 1..=12 {
            let total: f64 = fdb.terms(n).iter().map(|t| t.coeff).sum();
            assert_eq!(total as u128, bell_number(n), "n={n}");
        }
    }

    #[test]
    fn order3_terms_are_the_textbook_ones() {
        // (f∘g)''' = f'''·(g')³ + 3 f''·g'·g'' + f'·g'''
        let fdb = FaaDiBruno::new(3);
        let terms = fdb.terms(3);
        assert_eq!(terms.len(), 3);
        let find = |outer: usize| terms.iter().find(|t| t.outer_order == outer).unwrap();
        assert_eq!(find(3).coeff, 1.0);
        assert_eq!(find(3).factors, vec![(1, 3)]);
        assert_eq!(find(2).coeff, 3.0);
        assert_eq!(find(2).factors, vec![(1, 1), (2, 1)]);
        assert_eq!(find(1).coeff, 1.0);
        assert_eq!(find(1).factors, vec![(3, 1)]);
    }

    #[test]
    fn order4_coefficients() {
        // (f∘g)'''' : 1·f''''(g')⁴ + 6·f'''(g')²g'' + 3·f''(g'')² + 4·f''g'g''' + 1·f'g''''
        let fdb = FaaDiBruno::new(4);
        let mut coeffs: Vec<f64> = fdb.terms(4).iter().map(|t| t.coeff).collect();
        coeffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(coeffs, vec![1.0, 1.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn compose_scalar_chain_rule_order1() {
        let fdb = FaaDiBruno::new(4);
        // f(g) with f'(g)=2, g'(x)=3 → (f∘g)' = 6
        let f = [0.0, 2.0, 0.0, 0.0, 0.0];
        let g = [0.0, 3.0, 0.0, 0.0, 0.0];
        assert_eq!(fdb.compose_scalar(1, &f, &g), 6.0);
    }

    #[test]
    fn compose_scalar_matches_analytic_example() {
        // h(x) = exp(sin x): h^{(n)} computable since f=exp has all derivs
        // equal to exp(g), g=sin has the rotating tower.
        let fdb = FaaDiBruno::new(6);
        let x: f64 = 0.7;
        let e = x.sin().exp();
        let f: Vec<f64> = (0..=6).map(|_| e).collect();
        let g: Vec<f64> = (0..=6)
            .map(|k| match k % 4 {
                0 => x.sin(),
                1 => x.cos(),
                2 => -x.sin(),
                _ => -x.cos(),
            })
            .collect();
        // Analytic derivatives of exp(sin x) at x (via symbolic expansion):
        let s = x.sin();
        let c = x.cos();
        let h1 = e * c;
        let h2 = e * (c * c - s);
        let h3 = e * (c * c * c - 3.0 * s * c - c);
        let h4 = e * (c.powi(4) - 6.0 * s * c * c - 4.0 * c * c + 3.0 * s * s + s);
        for (n, expect) in [(1, h1), (2, h2), (3, h3), (4, h4)] {
            let got = fdb.compose_scalar(n, &f, &g);
            assert!(
                (got - expect).abs() < 1e-10 * expect.abs().max(1.0),
                "n={n}: {got} vs {expect}"
            );
        }
    }

    #[test]
    fn term_counts_follow_partition_function() {
        let fdb = FaaDiBruno::new(10);
        for n in 1..=10 {
            assert_eq!(fdb.terms(n).len() as u64, partition_count(n));
        }
        assert_eq!(fdb.total_terms(3), (1 + 2 + 3) as usize);
    }

    #[test]
    fn bell_numbers_oeis() {
        let expect: [u128; 9] = [1, 1, 2, 5, 15, 52, 203, 877, 4140];
        for (n, &e) in expect.iter().enumerate() {
            assert_eq!(bell_number(n), e, "B_{n}");
        }
    }

    #[test]
    #[should_panic(expected = "outside table")]
    fn out_of_range_order_panics() {
        FaaDiBruno::new(3).terms(4);
    }
}
