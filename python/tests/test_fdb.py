"""Tables: partitions, Faà di Bruno coefficients, tanh towers."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import fdb

# OEIS A000041
PARTITION_COUNTS = [1, 1, 2, 3, 5, 7, 11, 15, 22, 30, 42, 56, 77]
# OEIS A000110
BELL = [1, 1, 2, 5, 15, 52, 203, 877, 4140]


@pytest.mark.parametrize("n", range(13))
def test_partition_counts(n):
    assert len(fdb.partitions(n)) == PARTITION_COUNTS[n]


@given(st.integers(min_value=1, max_value=12))
def test_partitions_weights(n):
    for parts in fdb.partitions(n):
        assert sum(j * c for j, c in parts) == n
        assert all(c >= 1 for _, c in parts)
        js = [j for j, _ in parts]
        assert js == sorted(js)


@pytest.mark.parametrize("n", range(1, 9))
def test_coefficients_sum_to_bell(n):
    total = sum(c for c, _, _ in fdb.fdb_terms(n))
    assert total == BELL[n]


def test_order3_textbook_terms():
    # (f∘g)''' = f'''(g')^3 + 3 f'' g' g'' + f' g'''
    terms = {outer: coeff for coeff, outer, _ in fdb.fdb_terms(3)}
    assert terms == {3: 1.0, 2: 3.0, 1: 1.0}


def test_tanh_tower_low_orders():
    c = fdb.tanh_tower_coeffs(3)
    assert list(c[0]) == [0.0, 1.0]
    assert list(c[1]) == [1.0, 0.0, -1.0]
    assert list(c[2]) == [0.0, -2.0, 0.0, 2.0]
    assert list(c[3]) == [-2.0, 0.0, 8.0, 0.0, -6.0]


@given(st.floats(min_value=-2.0, max_value=2.0), st.integers(min_value=1, max_value=5))
@settings(max_examples=40)
def test_tanh_tower_matches_finite_difference(x, k):
    coeffs = fdb.tanh_tower_coeffs(k)

    def eval_poly(kk, t):
        acc = 0.0
        for c in reversed(coeffs[kk]):
            acc = acc * t + c
        return acc

    eps = 1e-6
    up = eval_poly(k - 1, math.tanh(x + eps))
    dn = eval_poly(k - 1, math.tanh(x - eps))
    fd = (up - dn) / (2 * eps)
    got = eval_poly(k, math.tanh(x))
    assert abs(got - fd) < 2e-4 * max(1.0, abs(got))


def test_bell_numbers():
    for n, b in enumerate(BELL):
        assert fdb.bell_number(n) == b
