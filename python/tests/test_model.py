"""L2 correctness: flat-theta plumbing, the model functions and the
Burgers PINN loss/gradients."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def flat_theta(key, sizes):
    m = model.param_count(sizes)
    return jax.random.normal(key, (m,), jnp.float64) * 0.3


def test_param_count_standard_pinn():
    assert model.param_count([1, 24, 24, 24, 1]) == 1273


@given(
    width=st.integers(min_value=1, max_value=12),
    depth=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_unflatten_layout(width, depth, seed):
    """Flat layout must match rust/src/nn/params.rs: W row-major, then b."""
    sizes = [1] + [width] * depth + [1]
    theta = flat_theta(jax.random.PRNGKey(seed), sizes)
    params = model.unflatten(theta, sizes)
    # Reassemble manually and compare.
    back = jnp.concatenate([jnp.concatenate([w.ravel(), b]) for w, b in params])
    np.testing.assert_array_equal(back, theta)
    assert params[0][0].shape == (width, 1)
    assert params[-1][0].shape == (1, width)


def test_ntp_forward_matches_autodiff_forward():
    sizes = [1, 12, 12, 1]
    theta = flat_theta(jax.random.PRNGKey(3), sizes)
    x = jnp.linspace(-1.0, 1.0, 16).reshape(-1, 1)
    for n in (1, 3, 5):
        a = model.ntp_forward(theta, x, n=n, sizes=sizes, use_pallas=False)
        b = model.autodiff_forward(theta, x, n=n, sizes=sizes)
        np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-9)


def test_pallas_and_ref_paths_agree():
    sizes = [1, 8, 8, 1]
    theta = flat_theta(jax.random.PRNGKey(5), sizes)
    x = jnp.linspace(-1.0, 1.0, 8).reshape(-1, 1)
    a = model.ntp_forward(theta, x, n=4, sizes=sizes, use_pallas=True)
    b = model.ntp_forward(theta, x, n=4, sizes=sizes, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-11, atol=1e-11)


def test_burgers_true_solution_properties():
    for k in (1, 2, 3):
        deg = 2 * k + 1
        for x in (-2.0, -0.5, 0.3, 1.7):
            u = model.burgers_true_u(x, k)
            assert abs(-u - u**deg - x) < 1e-9 * (1 + abs(x))
        assert model.burgers_true_u(0.0, k) == 0.0
        assert abs(model.burgers_true_du(0.0, k) + 1.0) < 1e-12


def test_residual_derivatives_leibniz_vs_autodiff():
    """Leibniz expansion == jax.grad of the residual wrt x."""
    sizes = [1, 8, 1]
    theta = flat_theta(jax.random.PRNGKey(11), sizes)
    lam = jnp.float64(0.4)
    xs = jnp.array([-0.7, 0.2, 1.1]).reshape(-1, 1)
    params = model.unflatten(theta, sizes)

    def r_scalar(x):
        def u_fn(xx):
            return ref.mlp_forward(params, xx.reshape(1, 1))[0, 0]

        u = u_fn(x)
        du = jax.grad(u_fn)(x)
        return -lam * u + ((1 + lam) * x + u) * du

    u = model.ntp_forward(theta, xs, n=3, sizes=sizes, use_pallas=False)
    got = model.residual_derivatives(u, xs, lam, 2)

    for j in range(3):
        fn = r_scalar
        for _ in range(j):
            fn = jax.grad(fn)
        expect = jnp.array([fn(x) for x in xs[:, 0]])
        np.testing.assert_allclose(got[j], expect, rtol=1e-8, atol=1e-9)


def test_pinn_value_grad_matches_fd():
    sizes = [1, 6, 1]
    theta = flat_theta(jax.random.PRNGKey(13), sizes)
    lam_raw = jnp.float64(0.1)
    x_res = jnp.linspace(-1.5, 1.5, 16).reshape(-1, 1)
    x_org = jnp.linspace(-0.1, 0.1, 8).reshape(-1, 1)

    loss, g_theta, g_lam = model.pinn_value_grad(
        theta, lam_raw, x_res, x_org, k=1, sizes=sizes, use_pallas=False
    )
    assert jnp.isfinite(loss) and loss > 0

    def loss_of(th, lr):
        return model.pinn_loss(th, lr, x_res, x_org, k=1, sizes=sizes, use_pallas=False)

    eps = 1e-6
    # λ_raw finite difference.
    fd_lam = (loss_of(theta, lam_raw + eps) - loss_of(theta, lam_raw - eps)) / (2 * eps)
    np.testing.assert_allclose(g_lam, fd_lam, rtol=1e-5, atol=1e-8)
    # Spot-check two theta coordinates.
    for i in (0, 7):
        e = jnp.zeros_like(theta).at[i].set(eps)
        fd = (loss_of(theta + e, lam_raw) - loss_of(theta - e, lam_raw)) / (2 * eps)
        np.testing.assert_allclose(g_theta[i], fd, rtol=1e-4, atol=1e-7)


def test_lambda_reparam_stays_in_bracket():
    sizes = [1, 4, 1]
    theta = flat_theta(jax.random.PRNGKey(17), sizes)
    x_res = jnp.zeros((4, 1))
    x_org = jnp.zeros((4, 1))
    # Extreme raw values must not blow up the loss (λ clamped by sigmoid).
    for lr in (-100.0, 0.0, 100.0):
        loss = model.pinn_loss(
            theta, jnp.float64(lr), x_res, x_org, k=2, sizes=sizes, use_pallas=False
        )
        assert jnp.isfinite(loss)
