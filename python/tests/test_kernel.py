"""L1 correctness: the Pallas kernel against the pure-jnp oracle, and the
oracle against nested-grad autodiff — across shapes, orders and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.ntp_layer import ntp_layer, vmem_footprint_bytes


def rand_params(key, sizes, dtype=jnp.float64):
    params = []
    for fan_in, fan_out in zip(sizes[:-1], sizes[1:]):
        key, k1, k2 = jax.random.split(key, 3)
        bound = (6.0 / (fan_in + fan_out)) ** 0.5
        w = jax.random.uniform(k1, (fan_out, fan_in), dtype, -bound, bound)
        b = jax.random.uniform(k2, (fan_out,), dtype, -0.1, 0.1)
        params.append((w, b))
    return params


@given(
    n=st.integers(min_value=1, max_value=6),
    batch_tiles=st.integers(min_value=1, max_value=3),
    f_in=st.integers(min_value=1, max_value=24),
    f_out=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pallas_layer_matches_ref(n, batch_tiles, f_in, f_out, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    bt = 8
    batch = bt * batch_tiles
    y = jax.random.normal(k1, (n + 1, batch, f_in), jnp.float64)
    w = jax.random.normal(k2, (f_out, f_in), jnp.float64) * 0.5
    b = jax.random.normal(k3, (f_out,), jnp.float64) * 0.1
    out_kernel = ntp_layer(y, w, b, block_batch=bt)
    out_ref = ref.ntp_layer_ref(y, w, b)
    np.testing.assert_allclose(out_kernel, out_ref, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_pallas_layer_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    y = jax.random.normal(key, (4, 16, 8), dtype)
    w = jnp.eye(8, dtype=dtype)
    b = jnp.zeros((8,), dtype)
    out = ntp_layer(y, w, b, block_batch=16)
    assert out.dtype == dtype
    ref_out = ref.ntp_layer_ref(y, w, b)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(out, ref_out, rtol=tol, atol=tol)


@given(
    n=st.integers(min_value=1, max_value=5),
    width=st.integers(min_value=2, max_value=16),
    depth=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_ntp_ref_matches_autodiff(n, width, depth, seed):
    """The paper's exactness claim, in JAX: single-pass Faà di Bruno
    propagation equals n nested reverse-mode differentiations."""
    sizes = [1] + [width] * depth + [1]
    params = rand_params(jax.random.PRNGKey(seed), sizes)
    x = jnp.linspace(-1.0, 1.0, 5).reshape(-1, 1)
    got = ref.ntp_forward_ref(params, x, n)
    expect = ref.autodiff_stack(params, x, n)
    np.testing.assert_allclose(got, expect, rtol=1e-8, atol=1e-9)


def test_full_forward_with_pallas_layers():
    """End-to-end channels through Pallas layers == autodiff, order 5."""
    sizes = [1, 16, 16, 1]
    params = rand_params(jax.random.PRNGKey(7), sizes)
    x = jnp.linspace(-1.5, 1.5, 8).reshape(-1, 1)
    n = 5
    w0, b0 = params[0]
    y = ref.seed_channels(x, w0, b0, n)
    for w, b in params[1:]:
        y = ntp_layer(y, w, b, block_batch=8)
    got = y[:, :, 0]
    expect = ref.autodiff_stack(params, x, n)
    np.testing.assert_allclose(got, expect, rtol=1e-8, atol=1e-9)


def test_vmem_footprint_under_budget():
    # Paper-scale worst case: n=9, tile 128, width 128 — must fit VMEM.
    assert vmem_footprint_bytes(9, 128, 128, 128) < 16 * 2**20


def test_kernel_rejects_ragged_tiles():
    y = jnp.zeros((2, 10, 4))
    w = jnp.zeros((4, 4))
    b = jnp.zeros((4,))
    with pytest.raises(AssertionError):
        ntp_layer(y, w, b, block_batch=3)
