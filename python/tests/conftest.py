import os
import sys

import jax

# The compile package is imported as `compile.*` relative to python/.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

jax.config.update("jax_enable_x64", True)
