"""Faà di Bruno / Bell coefficient tables and tanh derivative towers.

Build-time mirror of ``rust/src/ntp/{partitions,bell,activation}.rs`` —
the Python tests cross-check the two implementations through the lowered
artifacts, and the Pallas kernel unrolls these tables at trace time.
"""

from __future__ import annotations

import math
from functools import lru_cache


def partitions(n: int) -> list[list[tuple[int, int]]]:
    """All integer partitions of ``n`` in multiplicity form.

    Each partition is a list of ``(part_size j, count p_j)`` with ascending
    ``j`` and ``sum(j * p_j) == n``.
    """
    out: list[list[tuple[int, int]]] = []

    def rec(remaining: int, max_part: int, current: list[int]) -> None:
        if remaining == 0:
            mult: dict[int, int] = {}
            for p in current:
                mult[p] = mult.get(p, 0) + 1
            out.append(sorted(mult.items()))
            return
        for part in range(min(remaining, max_part), 0, -1):
            current.append(part)
            rec(remaining - part, part, current)
            current.pop()

    rec(n, max(n, 1), [])
    return out


def faa_di_bruno_coeff(n: int, parts: list[tuple[int, int]]) -> int:
    """C_p = n! / prod_j (p_j! * (j!)^p_j)  (exact integer)."""
    denom = 1
    for j, c in parts:
        denom *= math.factorial(c) * math.factorial(j) ** c
    return math.factorial(n) // denom


@lru_cache(maxsize=None)
def fdb_terms(n: int) -> tuple[tuple[float, int, tuple[tuple[int, int], ...]], ...]:
    """Terms ``(coeff, outer_order, factors)`` of the order-n FdB sum."""
    return tuple(
        (
            float(faa_di_bruno_coeff(n, parts)),
            sum(c for _, c in parts),
            tuple(parts),
        )
        for parts in partitions(n)
    )


@lru_cache(maxsize=None)
def tanh_tower_coeffs(n_max: int) -> tuple[tuple[float, ...], ...]:
    """Coefficients of P_k with tanh^{(k)}(x) = P_k(tanh x), k = 0..n_max.

    P_0 = t;  P_{k+1} = P_k'(t) * (1 - t^2).
    """
    coeffs: list[list[float]] = [[0.0, 1.0]]
    for _ in range(n_max):
        pk = coeffs[-1]
        dp = [pk[m] * m for m in range(1, len(pk))]
        nxt = [0.0] * (len(dp) + 2)
        for m, c in enumerate(dp):
            nxt[m] += c
            nxt[m + 2] -= c
        coeffs.append(nxt)
    return tuple(tuple(c) for c in coeffs)


def bell_number(n: int) -> int:
    """Bell numbers via the Bell triangle (sanity invariant for C_p)."""
    row = [1]
    for _ in range(n):
        nxt = [row[-1]]
        for v in row:
            nxt.append(nxt[-1] + v)
        row = nxt
    return row[0]
