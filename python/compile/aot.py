"""AOT lowering: JAX/Pallas model -> HLO text artifacts + manifest.json.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and executes via PJRT.

HLO *text* is the interchange format — NOT ``lowered.compile().serialize()``
— because jax >= 0.5 emits protos with 64-bit instruction ids that the
pinned xla_extension 0.5.1 rejects; the text parser reassigns ids.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

SIZES = [1, 24, 24, 24, 1]  # the paper's standard PINN architecture
BATCH = 256                 # compiled batch of the forward artifacts
PINN_RES = 256              # residual collocation batch of the vg artifact
PINN_ORG = 32               # near-origin batch


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_ntp_fwd(n: int, use_pallas: bool = True):
    m = model.param_count(SIZES)
    fn = functools.partial(model.ntp_forward, n=n, sizes=SIZES, use_pallas=use_pallas)
    theta = jax.ShapeDtypeStruct((m,), jnp.float64)
    x = jax.ShapeDtypeStruct((BATCH, 1), jnp.float64)
    return jax.jit(fn).lower(theta, x)


def lower_autodiff_fwd(n: int):
    m = model.param_count(SIZES)
    fn = functools.partial(model.autodiff_forward, n=n, sizes=SIZES)
    theta = jax.ShapeDtypeStruct((m,), jnp.float64)
    x = jax.ShapeDtypeStruct((BATCH, 1), jnp.float64)
    return jax.jit(fn).lower(theta, x)


def lower_pinn_vg(k: int):
    m = model.param_count(SIZES)
    # use_pallas=False: interpret-mode pallas_call does not support
    # reverse-mode linearization, so the differentiated (training)
    # artifact lowers through the pure-jnp layer step. The forward
    # artifacts keep the Pallas kernel.
    fn = functools.partial(model.pinn_value_grad, k=k, sizes=SIZES, use_pallas=False)
    theta = jax.ShapeDtypeStruct((m,), jnp.float64)
    lam = jax.ShapeDtypeStruct((), jnp.float64)
    x_res = jax.ShapeDtypeStruct((PINN_RES, 1), jnp.float64)
    x_org = jax.ShapeDtypeStruct((PINN_ORG, 1), jnp.float64)
    return jax.jit(fn).lower(theta, lam, x_res, x_org)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick", action="store_true", help="only lower the d3 forward artifact (CI smoke)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    m = model.param_count(SIZES)
    jobs = [
        ("ntp_fwd_d3", lambda: lower_ntp_fwd(3), {"n_derivs": 3}),
    ]
    if not args.quick:
        jobs += [
            ("ntp_fwd_d7", lambda: lower_ntp_fwd(7), {"n_derivs": 7}),
            ("autodiff_fwd_d3", lambda: lower_autodiff_fwd(3), {"n_derivs": 3}),
            ("pinn_vg_k1", lambda: lower_pinn_vg(1), {"k": 1}),
        ]

    manifest = {"artifacts": []}
    for name, build, extra in jobs:
        print(f"lowering {name} ...", flush=True)
        text = to_hlo_text(build())
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "batch": PINN_RES if name.startswith("pinn") else BATCH,
            "n_params": m,
            "sizes": SIZES,
        }
        entry.update(extra)
        manifest["artifacts"].append(entry)
        print(f"  wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
