"""L1: the n-TangentProp layer as a Pallas kernel.

The per-layer hot spot of the algorithm: the tanh derivative tower, the
Faà di Bruno channel combine (eq. 5b) and the layer matmul (eq. 5a), fused
into one kernel invocation per batch tile.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the whole channel block
``[n+1, Bt, F_in]`` lives in VMEM; the tower + combine are straight-line
VPU code (the partition structure is *static* — tables unroll at trace
time, no gathers); the channel matmul batches into a single
``[(n+1)·Bt, F_in] × [F_in, F_out]`` MXU contraction.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated in DESIGN.md from the
VMEM footprint and MXU utilization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import fdb

jax.config.update("jax_enable_x64", True)


def _kernel(y_ref, w_ref, b_ref, o_ref, *, n: int):
    """One batch tile: channels [n+1, Bt, Fin] -> [n+1, Bt, Fout]."""
    y = y_ref[...]  # [n+1, Bt, Fin], resident in VMEM
    w = w_ref[...]  # [Fout, Fin]
    b = b_ref[...]  # [Fout]

    # --- tanh derivative tower, shared powers of t (VPU) ---------------
    coeffs = fdb.tanh_tower_coeffs(n)
    t = jnp.tanh(y[0])
    towers = []
    for k in range(n + 1):
        c = coeffs[k]
        acc = jnp.full_like(t, c[-1])
        for m in range(len(c) - 2, -1, -1):
            acc = acc * t + c[m]
        towers.append(acc)

    # --- Faà di Bruno combine, statically unrolled (VPU) ---------------
    xi = [towers[0]]
    for i in range(1, n + 1):
        z = jnp.zeros_like(t)
        for coeff, outer, factors in fdb.fdb_terms(i):
            prod = coeff * towers[outer]
            for j, c in factors:
                prod = prod * y[j] ** c
            z = z + prod
        xi.append(z)
    stacked = jnp.stack(xi)  # [n+1, Bt, Fin]

    # --- layer matmul for all channels at once (MXU) -------------------
    flat = stacked.reshape(-1, stacked.shape[-1])  # [(n+1)*Bt, Fin]
    out = jnp.dot(flat, w.T).reshape(n + 1, y.shape[1], w.shape[0])
    out = out.at[0].add(b)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("block_batch",))
def _noop(x, block_batch=0):  # pragma: no cover - placeholder for jit cache
    return x


def ntp_layer(
    y: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, block_batch: int | None = None
) -> jnp.ndarray:
    """Pallas-accelerated n-TangentProp layer step.

    y: [n+1, B, F_in] channels; w: [F_out, F_in]; b: [F_out].
    Returns [n+1, B, F_out]. The batch axis is tiled with BlockSpec.
    """
    n = y.shape[0] - 1
    batch = y.shape[1]
    f_in = y.shape[2]
    f_out = w.shape[0]
    bt = block_batch or min(batch, 128)
    assert batch % bt == 0, f"batch {batch} not divisible by tile {bt}"

    return pl.pallas_call(
        functools.partial(_kernel, n=n),
        grid=(batch // bt,),
        in_specs=[
            pl.BlockSpec((n + 1, bt, f_in), lambda i: (0, i, 0)),
            pl.BlockSpec((f_out, f_in), lambda i: (0, 0)),
            pl.BlockSpec((f_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n + 1, bt, f_out), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + 1, batch, f_out), y.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(y, w, b)


def vmem_footprint_bytes(n: int, bt: int, f_in: int, f_out: int, dtype_bytes: int = 8) -> int:
    """Estimated VMEM residency of one kernel invocation — used by the
    DESIGN.md roofline discussion (must stay well under ~16 MB/core)."""
    channels_in = (n + 1) * bt * f_in
    channels_out = (n + 1) * bt * f_out
    towers = (n + 1) * bt * f_in
    weights = f_out * f_in + f_out
    return dtype_bytes * (channels_in + channels_out + towers + weights)
