"""Pure-jnp oracle for the n-TangentProp layer and full forward pass.

This is the correctness reference the Pallas kernel is tested against
(L1 vs ref), and itself is validated against nested-``jax.grad``
autodifferentiation (the exactness property of the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import fdb

jax.config.update("jax_enable_x64", True)


def tanh_towers(y0: jnp.ndarray, n: int) -> list[jnp.ndarray]:
    """[sigma^(s)(y0) for s in 0..n] via the polynomial tower in t=tanh."""
    coeffs = fdb.tanh_tower_coeffs(n)
    t = jnp.tanh(y0)
    towers = []
    for k in range(n + 1):
        c = coeffs[k]
        acc = jnp.zeros_like(t) + c[-1]
        for m in range(len(c) - 2, -1, -1):
            acc = acc * t + c[m]
        towers.append(acc)
    return towers


def fdb_combine(towers: list[jnp.ndarray], y: list[jnp.ndarray], i: int) -> jnp.ndarray:
    """xi_i = sum_p C_p sigma^(|p|)(y0) prod_j y_j^{p_j}   (eq. 5b)."""
    z = jnp.zeros_like(y[0])
    for coeff, outer, factors in fdb.fdb_terms(i):
        prod = coeff * towers[outer]
        for j, c in factors:
            prod = prod * y[j] ** c
        z = z + prod
    return z


def ntp_layer_ref(y: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """One hidden-layer step of n-TangentProp.

    ``y``: [n+1, B, F_in] channels of the previous layer's pre-activation;
    returns [n+1, B, F_out] channels of this layer's pre-activation.
    """
    n = y.shape[0] - 1
    chans = [y[i] for i in range(n + 1)]
    towers = tanh_towers(chans[0], n)
    xi = [towers[0]] + [fdb_combine(towers, chans, i) for i in range(1, n + 1)]
    out = [xi[0] @ w.T + b] + [x @ w.T for x in xi[1:]]
    return jnp.stack(out)


def seed_channels(x: jnp.ndarray, w0: jnp.ndarray, b0: jnp.ndarray, n: int) -> jnp.ndarray:
    """First affine layer: y0 = xW^T+b, y1 = 1·W^T, y_i = 0 (i >= 2)."""
    batch = x.shape[0]
    y0 = x @ w0.T + b0
    chans = [y0]
    if n >= 1:
        chans.append(jnp.ones((batch, 1), dtype=x.dtype) @ w0.T)
    for _ in range(2, n + 1):
        chans.append(jnp.zeros_like(y0))
    return jnp.stack(chans)


def ntp_forward_ref(
    params: list[tuple[jnp.ndarray, jnp.ndarray]], x: jnp.ndarray, n: int
) -> jnp.ndarray:
    """Full n-TangentProp forward: returns [n+1, B] (output dim squeezed)."""
    w0, b0 = params[0]
    y = seed_channels(x, w0, b0, n)
    for w, b in params[1:]:
        y = ntp_layer_ref(y, w, b)
    return y[:, :, 0]


def mlp_forward(params: list[tuple[jnp.ndarray, jnp.ndarray]], x: jnp.ndarray) -> jnp.ndarray:
    """Plain tanh MLP forward (linear head), x: [B,1] -> [B,1]."""
    h = x
    for i, (w, b) in enumerate(params):
        h = h @ w.T + b
        if i != len(params) - 1:
            h = jnp.tanh(h)
    return h


def autodiff_stack(
    params: list[tuple[jnp.ndarray, jnp.ndarray]], x: jnp.ndarray, n: int
) -> jnp.ndarray:
    """Baseline: [u, u', ..., u^(n)] via repeated reverse-mode autodiff
    (the exponential path the paper measures against)."""

    def u_sum(xx):
        return mlp_forward(params, xx).sum()

    stacks = [mlp_forward(params, x)[:, 0]]
    fn = u_sum
    for _ in range(n):
        g = jax.grad(fn)
        stacks.append(g(x)[:, 0])
        fn = (lambda gg: lambda xx: gg(xx).sum())(g)
    return jnp.stack(stacks)
