"""L2: the JAX model — n-TangentProp forward (calling the L1 Pallas
kernel), the repeated-autodiff baseline, and the Burgers PINN value+grad
used for training from Rust.

Everything here is *build-time only*: ``aot.py`` lowers these functions to
HLO text once; the Rust runtime executes the artifacts thereafter.

Parameter layout matches ``rust/src/nn/params.rs`` exactly:
flat theta = concat(W0.ravel(), b0, W1.ravel(), b1, ...) with W: [out, in]
row-major, so a vector trained in Rust is directly loadable here.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.ntp_layer import ntp_layer

jax.config.update("jax_enable_x64", True)


# --------------------------------------------------------------- params

def param_count(sizes: list[int]) -> int:
    return sum(o * i + o for i, o in zip(sizes[:-1], sizes[1:]))


def unflatten(theta: jnp.ndarray, sizes: list[int]) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
    """Split a flat theta into [(W, b), ...] (Rust slot order)."""
    params = []
    off = 0
    for fan_in, fan_out in zip(sizes[:-1], sizes[1:]):
        w = theta[off : off + fan_out * fan_in].reshape(fan_out, fan_in)
        off += fan_out * fan_in
        b = theta[off : off + fan_out]
        off += fan_out
        params.append((w, b))
    return params


# --------------------------------------------------------------- models

def ntp_forward(
    theta: jnp.ndarray, x: jnp.ndarray, *, n: int, sizes: list[int], use_pallas: bool = True
) -> jnp.ndarray:
    """n-TangentProp forward: [u, u', ..., u^(n)] stacked as [n+1, B].

    ``use_pallas`` switches the per-layer step between the L1 kernel and
    the pure-jnp reference (both lower into the same HLO artifact shape).
    """
    params = unflatten(theta, sizes)
    w0, b0 = params[0]
    y = ref.seed_channels(x, w0, b0, n)
    step = ntp_layer if use_pallas else ref.ntp_layer_ref
    for w, b in params[1:]:
        y = step(y, w, b)
    return y[:, :, 0]


def autodiff_forward(
    theta: jnp.ndarray, x: jnp.ndarray, *, n: int, sizes: list[int]
) -> jnp.ndarray:
    """Baseline artifact: repeated reverse-mode autodiff stack [n+1, B]."""
    params = unflatten(theta, sizes)
    return ref.autodiff_stack(params, x, n)


# ------------------------------------------------------- Burgers PINN

def _binom(j: int, i: int) -> float:
    return float(math.comb(j, i))


def residual_derivatives(
    u: jnp.ndarray, x: jnp.ndarray, lam: jnp.ndarray, j_max: int
) -> list[jnp.ndarray]:
    """Leibniz expansion of ∂_x^j R for the profile ODE
    R = -λU + ((1+λ)x + U) U', given channels u: [n+1, B]."""
    out = []
    xb = x[:, 0]
    for j in range(j_max + 1):
        t1 = -lam * u[j]
        inner = xb * u[j + 1] + (j * u[j] if j > 0 else 0.0)
        t2 = (1.0 + lam) * inner
        t3 = sum(_binom(j, i) * u[i] * u[j + 1 - i] for i in range(j + 1))
        out.append(t1 + t2 + t3)
    return out


def burgers_true_u(x: float, k: int, c: float = 1.0) -> float:
    """Ground truth via Newton on X = -U - C·U^(2k+1) (python float math,
    used only to bake anchor targets into the artifact at trace time)."""
    if x == 0.0:
        return 0.0
    deg = 2 * k + 1
    u = -x / (1.0 + c)
    lo, hi = (-(abs(x) + 1.0), abs(x) + 1.0)
    for _ in range(200):
        f = -u - c * u**deg - x
        if abs(f) < 1e-15 * (1.0 + abs(x)):
            break
        df = -1.0 - c * deg * u ** (deg - 1)
        nxt = u - f / df
        u = nxt if lo < nxt < hi else 0.5 * (lo + hi)
        # maintain bracket (X(U) decreasing)
        if -u - c * u**deg - x > 0.0:
            lo = u
        else:
            hi = u
    return u


def burgers_true_du(x: float, k: int, c: float = 1.0) -> float:
    u = burgers_true_u(x, k, c)
    deg = 2 * k + 1
    return -1.0 / (1.0 + c * deg * u ** (deg - 1))


def pinn_loss(
    theta: jnp.ndarray,
    lam_raw: jnp.ndarray,
    x_res: jnp.ndarray,
    x_org: jnp.ndarray,
    *,
    k: int,
    sizes: list[int],
    x_max: float = 2.0,
    m_sobolev: int = 1,
    q_weights: tuple[float, ...] = (1.0, 0.1),
    w_high: float = 0.05,
    w_bc: float = 10.0,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """The Burgers profile loss (same structure as rust/src/pinn/loss.rs)."""
    n = 2 * k + 1
    lo, hi = 1.0 / (2 * k + 1), 1.0 / (2 * k - 1)
    lam = lo + (hi - lo) * jax.nn.sigmoid(lam_raw)

    # Sobolev residual terms over the domain cloud.
    u_res = ntp_forward(theta, x_res, n=m_sobolev + 1, sizes=sizes, use_pallas=use_pallas)
    r = residual_derivatives(u_res, x_res, lam, m_sobolev)
    loss = sum(q * jnp.mean(rj**2) for q, rj in zip(q_weights, r))

    # High-order smoothness near the origin (L*).
    k2 = 2 * k
    u_org = ntp_forward(theta, x_org, n=n, sizes=sizes, use_pallas=use_pallas)
    r_org = residual_derivatives(u_org, x_org, lam, k2)
    fact = float(math.factorial(k2 + 1))
    loss = loss + w_high / (fact * fact) * jnp.mean(r_org[k2] ** 2)

    # Anchors at {0, ±x_max} on u and u' (targets baked at trace time).
    bc_x = [0.0, -x_max, x_max]
    bc_u = jnp.array([burgers_true_u(x, k) for x in bc_x])
    bc_du = jnp.array([burgers_true_du(x, k) for x in bc_x])
    u_bc = ntp_forward(theta, jnp.array(bc_x).reshape(-1, 1), n=1, sizes=sizes, use_pallas=use_pallas)
    bc_term = jnp.mean((u_bc[0] - bc_u) ** 2) + jnp.mean((u_bc[1] - bc_du) ** 2)
    return loss + w_bc * bc_term


def pinn_value_grad(theta, lam_raw, x_res, x_org, *, k: int, sizes: list[int], **kw):
    """(loss, dloss/dtheta, dloss/dlam_raw) — the training-step artifact."""
    loss, (g_theta, g_lam) = jax.value_and_grad(
        lambda th, lr: pinn_loss(th, lr, x_res, x_org, k=k, sizes=sizes, **kw),
        argnums=(0, 1),
    )(theta, lam_raw)
    return loss, g_theta, g_lam
