//! A small, offline, API-compatible subset of the `anyhow` crate.
//!
//! The offline build environment cannot fetch crates.io dependencies, so
//! this vendored shim provides the pieces the repository actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a context
//! chain of display strings; `{:#}` formatting joins the chain with
//! `": "` like the real crate.

use std::fmt;

/// A context-carrying error value. `chain[0]` is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` specialized to [`Error`], with the same default-parameter
/// shape as `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost → innermost context messages.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(cause) = src {
            chain.push(cause.to_string());
            src = cause.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn option_context() {
        let e = None::<u8>.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {}", flag);
            bail!("unreachable for true? no: always bails");
        }
        assert!(f(false).unwrap_err().to_string().contains("false"));
        assert!(f(true).unwrap_err().to_string().contains("bails"));
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
    }

    #[test]
    fn from_std_error_keeps_source_chain() {
        let e: Error = io_err().into();
        assert_eq!(e.root_cause(), "root cause");
    }
}
