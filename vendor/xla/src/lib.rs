//! Offline stub of the `xla` (xla_extension) bindings.
//!
//! The offline build environment has no PJRT shared library, so this crate
//! provides the small API surface `ntangent::runtime` uses: [`Literal`] is
//! fully functional in memory (construction, reshape, dtype/shape queries,
//! tuple access), while the PJRT client/executable entry points return
//! [`Error`] so callers degrade gracefully (`ntangent info` prints
//! "PJRT unavailable", the serve `pjrt` backend surfaces the error, and
//! the integration tests skip themselves). Swapping a real PJRT-backed
//! crate back in is a one-line change in the workspace `Cargo.toml`.

use std::fmt;

/// Stub error type (implements `std::error::Error`, so it converts into
/// `anyhow::Error` through the blanket impl).
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this build (offline xla stub)"
    ))
}

/// Element dtypes the runtime bridge recognizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Scalar types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f64(x: f64) -> Self;
}

impl NativeType for f64 {
    fn from_f64(x: f64) -> f64 {
        x
    }
}

impl NativeType for f32 {
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
}

/// Shape of a dense array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// An in-memory literal: dense `f64` storage plus a declared dtype, or a
/// tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
    ty: ElementType,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// A rank-1 `f64` literal.
    pub fn vec1(values: &[f64]) -> Literal {
        Literal {
            data: values.to_vec(),
            dims: vec![values.len() as i64],
            ty: ElementType::F64,
            tuple: None,
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("array_shape of a tuple literal".into()));
        }
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f64(x)).collect())
    }

    /// The elements of a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(elems) => Ok(elems.clone()),
            None => Err(Error("to_tuple of a non-tuple literal".into())),
        }
    }
}

/// Stub PJRT client: construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_in_memory() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.ty().unwrap(), ElementType::F64);
        assert_eq!(r.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn pjrt_entry_points_error_not_panic() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
    }
}
