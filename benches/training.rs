//! Bench: serial vs data-parallel PINN training — the sharded objective's
//! gradient accumulation under different worker policies, plus a short
//! Adam phase end-to-end. Every parallel gradient is checked bitwise
//! against serial before timing.
//!
//!     cargo bench --bench training

use ntangent::nn::Mlp;
use ntangent::ntp::ParallelPolicy;
use ntangent::opt::{Adam, Objective};
use ntangent::pinn::{BurgersLossSpec, DerivEngine, ParallelObjective};
use ntangent::util::prng::Prng;
use ntangent::util::stats::Summary;
use ntangent::util::timer::time_trials;

fn bench(name: &str, warmup: usize, trials: usize, mut f: impl FnMut()) -> f64 {
    let ts = time_trials(warmup, trials, || f());
    let s = Summary::of(&ts);
    println!(
        "{name:<52} mean {:>9.2} ms   p95 {:>9.2} ms",
        s.mean * 1e3,
        s.p95 * 1e3
    );
    s.mean
}

fn main() {
    let mut spec = BurgersLossSpec::for_profile(1);
    spec.n_res = 512;
    spec.n_org = 64;
    let chunk = 32;
    println!(
        "# pinn training, sharded objective (3x24 net, {} res + {} org pts, chunk {chunk})",
        spec.n_res, spec.n_org
    );

    let mut rng = Prng::seeded(17);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    let mut obj = ParallelObjective::build(
        spec,
        &mlp,
        DerivEngine::Ntp,
        ParallelPolicy::Serial,
        chunk,
        &mut rng,
    );
    let theta = obj.theta_init(&mlp);
    println!(
        "# {} shards, {} tape nodes total",
        obj.n_shards(),
        obj.graph_len()
    );

    // --- One gradient accumulation, serial vs Fixed(t) -----------------
    let (_, want) = obj.value_grad(&theta);
    let serial = bench("value+grad serial", 2, 10, || {
        std::hint::black_box(obj.value_grad(&theta));
    });
    for threads in [2usize, 4, 8] {
        obj.set_policy(ParallelPolicy::Fixed(threads));
        let (_, got) = obj.value_grad(&theta);
        assert_eq!(want, got, "t={threads}: gradient not bitwise serial-equal");
        let par = bench(&format!("value+grad Fixed({threads})"), 2, 10, || {
            std::hint::black_box(obj.value_grad(&theta));
        });
        println!("{:<52} speedup {:.2}x", format!("  -> vs serial (t={threads})"), serial / par);
    }

    // --- Forward-only (the L-BFGS line-search cost) ---------------------
    obj.set_policy(ParallelPolicy::Serial);
    let fwd_serial = bench("value-only serial", 2, 10, || {
        std::hint::black_box(obj.value(&theta));
    });
    obj.set_policy(ParallelPolicy::Fixed(4));
    let fwd_par = bench("value-only Fixed(4)", 2, 10, || {
        std::hint::black_box(obj.value(&theta));
    });
    println!("{:<52} speedup {:.2}x", "  -> vs serial", fwd_serial / fwd_par);

    // --- Short Adam phase end-to-end ------------------------------------
    for policy in [ParallelPolicy::Serial, ParallelPolicy::Fixed(4)] {
        obj.set_policy(policy);
        bench(&format!("20 Adam epochs {policy:?}"), 0, 3, || {
            let mut adam = Adam::new(obj.dim(), 1e-3).with_policy(policy);
            let mut th = theta.clone();
            for _ in 0..20 {
                adam.step(&mut obj, &mut th);
            }
            std::hint::black_box(&th);
        });
    }
    println!("\n(gradients checked bitwise serial==parallel before timing)");
}
