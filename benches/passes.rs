//! Bench: forward/backward pass times, autodiff vs n-TangentProp
//! (the hot-path measurement behind Figs 1-3), hand-rolled harness
//! (criterion is unavailable offline).
//!
//!     cargo bench --bench passes

use ntangent::bench::{standard_mlp, time_pass_avg, Engine};
use ntangent::util::stats::Summary;
use ntangent::util::timer::time_trials;

fn main() {
    let (mlp, x) = standard_mlp(7);
    println!("# passes: 3x24 tanh net, batch 256 (M = {} params)", mlp.n_params());
    println!(
        "{:<16} {:>3} {:>12} {:>12} {:>12} {:>9}",
        "engine", "n", "fwd (ms)", "bwd (ms)", "total (ms)", "ratio"
    );
    for n in [1usize, 2, 3, 4, 5, 6] {
        let ntp = time_pass_avg(Engine::Ntp, &mlp, &x, n, 1, 5);
        // Cap autodiff effort at n=6; it is already >100x slower there.
        let ad = time_pass_avg(Engine::Autodiff, &mlp, &x, n, if n < 5 { 1 } else { 0 }, if n < 5 { 5 } else { 2 });
        for (name, t) in [("ntangentprop", ntp), ("autodiff", ad)] {
            println!(
                "{name:<16} {n:>3} {:>12.3} {:>12.3} {:>12.3} {:>9.2}",
                t.fwd * 1e3,
                t.bwd * 1e3,
                t.total() * 1e3,
                ad.total() / ntp.total()
            );
        }
    }

    // Stability: repeated ntp-forward timing spread at n=4.
    let engine = ntangent::ntp::NtpEngine::new(4);
    let ts = time_trials(3, 15, || {
        std::hint::black_box(engine.forward(&mlp, &x));
    });
    let s = Summary::of(&ts);
    println!(
        "\nntp pure forward n=4: mean {:.3} ms  p5 {:.3}  p95 {:.3}  (15 trials)",
        s.mean * 1e3,
        s.p5 * 1e3,
        s.p95 * 1e3
    );
}
