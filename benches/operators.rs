//! Bench: multivariate PDE operators — the directional n-TangentProp
//! path (direction-stacked fused batches + exact recombination) against
//! the nested-tape autodiff baseline, plus the raw directional-jet
//! kernel across orders and direction counts.
//!
//!     cargo bench --bench operators

use ntangent::bench::operators::{self as bench_operators, OperatorBenchConfig};
use ntangent::nn::Mlp;
use ntangent::ntp::{MultiJetEngine, ParallelPolicy};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use ntangent::util::stats::Summary;
use ntangent::util::timer::time_trials;

fn bench(name: &str, trials: usize, mut f: impl FnMut()) {
    let ts = time_trials(2, trials, || f());
    let s = Summary::of(&ts);
    println!(
        "{name:<52} mean {:>10.1} µs   p95 {:>10.1} µs",
        s.mean * 1e6,
        s.p95 * 1e6
    );
}

fn main() {
    let mut rng = Prng::seeded(17);
    println!("# directional jets (3x24 tanh net, 2-D input)");
    let mlp = Mlp::uniform(2, 24, 3, 1, &mut rng);
    let x = Tensor::rand_uniform(&[1024, 2], -1.0, 1.0, &mut rng);

    // Raw jet cost across orders: D directions × one fused [D·B] batch.
    for n in [2usize, 3, 4] {
        let engine = MultiJetEngine::new(2, n);
        let d = engine.plan().n_directions();
        bench(
            &format!("jet n={n} D={d} (B=1024, serial)"),
            12,
            || {
                std::hint::black_box(engine.jet(&mlp, &x));
            },
        );
        let par = MultiJetEngine::with_policy(2, n, ParallelPolicy::Fixed(4));
        bench(
            &format!("jet n={n} D={d} (B=1024, Fixed(4))"),
            12,
            || {
                std::hint::black_box(par.jet(&mlp, &x));
            },
        );
    }

    // The operator head-to-head at the CI smoke shape (full-size numbers
    // come from `ntangent bench operators`).
    println!("\n# operator head-to-head (smoke shape)");
    let cfg = OperatorBenchConfig::smoke();
    let cells = bench_operators::run(&cfg, |msg| eprintln!("[bench] {msg}"));
    print!("{}", bench_operators::summarize(&cells));
}
