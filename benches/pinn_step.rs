//! Bench: one PINN training step (value+grad) per engine and profile —
//! the quantity that multiplies into the Fig 6-10 end-to-end times.
//!
//!     cargo bench --bench pinn_step

use ntangent::nn::Mlp;
use ntangent::opt::Objective;
use ntangent::pinn::{BurgersLossSpec, DerivEngine, PinnObjective};
use ntangent::util::prng::Prng;
use ntangent::util::stats::Summary;
use ntangent::util::timer::time_trials;

fn main() {
    println!("# pinn training step (3x24 net, 128 residual + 32 origin pts)");
    println!(
        "{:<12} {:<10} {:>14} {:>14} {:>12}",
        "profile", "engine", "value (ms)", "value+grad(ms)", "graph nodes"
    );
    for k in [1usize, 2] {
        for engine in [DerivEngine::Ntp, DerivEngine::Autodiff] {
            // Autodiff at k=2 needs 5 derivatives — already slow; trim trials.
            let trials = if engine == DerivEngine::Autodiff && k >= 2 { 3 } else { 10 };
            let mut rng = Prng::seeded(17);
            let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
            let spec = BurgersLossSpec::for_profile(k);
            let mut obj = PinnObjective::build(spec, &mlp, engine, &mut rng);
            let theta = obj.theta_init(&mlp);

            let tv = time_trials(1, trials, || {
                std::hint::black_box(obj.value(&theta));
            });
            let tg = time_trials(1, trials, || {
                std::hint::black_box(obj.value_grad(&theta));
            });
            println!(
                "k={k:<10} {:<10} {:>14.2} {:>14.2} {:>12}",
                format!("{engine:?}"),
                Summary::of(&tv).mean * 1e3,
                Summary::of(&tg).mean * 1e3,
                obj.graph_len()
            );
        }
    }
    println!("\n(value-only is the L-BFGS line-search cost — the Fig 6 mechanism)");
}
