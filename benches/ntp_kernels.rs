//! Bench: micro-kernels of the n-TangentProp hot path — tanh tower,
//! Faà di Bruno combine, channel matmul, and the fused element-tiled
//! kernel against the pre-fusion reference path.
//!
//!     cargo bench --bench ntp_kernels

#[cfg(feature = "reference-oracle")]
use ntangent::bench::kernels::{self as bench_kernels, KernelBenchConfig};
use ntangent::bench::parallel::{self as bench_parallel, ParallelBenchConfig};
use ntangent::nn::Mlp;
use ntangent::ntp::{ActivationKind, NtpEngine, SmoothActivation};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use ntangent::util::stats::Summary;
use ntangent::util::timer::time_trials;

fn bench(name: &str, trials: usize, mut f: impl FnMut()) {
    let ts = time_trials(3, trials, || f());
    let s = Summary::of(&ts);
    println!(
        "{name:<44} mean {:>9.1} µs   p95 {:>9.1} µs",
        s.mean * 1e6,
        s.p95 * 1e6
    );
}

fn main() {
    let mut rng = Prng::seeded(3);
    println!("# ntp micro-kernels (batch 256, width 24)");

    let z = Tensor::rand_normal(&[256, 24], 0.0, 1.0, &mut rng);

    // Per-activation tower cost: tanh's polynomial recurrence vs the sine
    // 4-cycle vs the logistic polynomials vs the GELU Hermite tower.
    for kind in ActivationKind::ALL {
        for n in [3usize, 6, 9] {
            let act = kind.build_tower(n);
            bench(
                &format!("{} tower n={n} [256x24]", kind.name()),
                30,
                || {
                    std::hint::black_box(act.tower(&z, n));
                },
            );
        }
    }

    for kind in ActivationKind::ALL {
        for n in [3usize, 6, 9] {
            let engine = NtpEngine::new(n);
            let mlp = Mlp::uniform_with(1, 24, 3, 1, kind, &mut Prng::seeded(5));
            let x = Tensor::rand_uniform(&[256, 1], -1.0, 1.0, &mut Prng::seeded(6));
            bench(
                &format!("ntp full forward n={n} (3x24 {}, B=256)", kind.name()),
                20,
                || {
                    std::hint::black_box(engine.forward(&mlp, &x));
                },
            );
        }
    }

    // Fused element-tiled kernel vs the pre-fusion reference path at the
    // acceptance shape of the kernel-fusion PR (width 64, depth 4,
    // B = 4096, n = 4/6/8). Shares the measurement protocol (and the
    // differential fused-vs-reference check) with `ntangent bench
    // kernels` via `bench::kernels`. The reference oracle is
    // feature-gated, so this leg needs `--features reference-oracle`.
    #[cfg(feature = "reference-oracle")]
    {
        println!("# fused kernel vs reference (4x64 tanh, B=4096)");
        let kernel_cfg = KernelBenchConfig {
            warmup: 1,
            trials: 5,
            ..KernelBenchConfig::default()
        };
        print!("{}", bench_kernels::summarize(&bench_kernels::run(&kernel_cfg, |_| {})));
    }
    #[cfg(not(feature = "reference-oracle"))]
    println!("# fused kernel vs reference: skipped (needs --features reference-oracle)");

    // Serial vs chunked-parallel forward at the serving shape (the
    // acceptance point of the parallel-execution PR: B >= 4096, n = 4).
    // Shares the measurement protocol (and the bitwise serial-equality
    // check) with `ntangent bench par` via `bench::parallel`.
    println!("# parallel forward: serial vs Fixed(t) (3x24 tanh, n=4)");
    let par_cfg = ParallelBenchConfig {
        batches: vec![1024, 4096],
        threads: vec![2, 4, 8],
        warmup: 3,
        trials: 15,
        ..ParallelBenchConfig::default()
    };
    print!("{}", bench_parallel::summarize(&bench_parallel::run(&par_cfg, |_| {})));

    // Raw matmul roofline of the substrate.
    for size in [24usize, 64, 128] {
        let a = Tensor::rand_normal(&[256, size], 0.0, 1.0, &mut rng);
        let w = Tensor::rand_normal(&[size, size], 0.0, 1.0, &mut rng);
        let flops = 2.0 * 256.0 * (size * size) as f64;
        let ts = time_trials(3, 20, || {
            std::hint::black_box(a.matmul_nt(&w));
        });
        let s = Summary::of(&ts);
        println!(
            "matmul_nt [256x{size}]x[{size}x{size}]          mean {:>9.1} µs   {:>7.2} GFLOP/s",
            s.mean * 1e6,
            flops / s.mean / 1e9
        );
    }
}
