//! Bench: coordinator throughput/latency — request batching over the
//! native backend: the single-worker hot loop, then the sharded
//! multi-worker pool under mixed-activation traffic (1 vs 2 vs 4 workers
//! on the same load, so the speedup is read straight off the req/s
//! column).
//!
//!     cargo bench --bench coordinator

use ntangent::coordinator::{BatcherConfig, NativeBackend, Service};
use ntangent::nn::Mlp;
use ntangent::ntp::ActivationKind;
use ntangent::util::prng::Prng;
use std::time::Instant;

fn main() {
    let mut rng = Prng::seeded(31);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    println!("# coordinator: n=3 channels, native backend, batch cap 256");
    println!(
        "{:>8} {:>10} {:>14} {:>14} {:>12} {:>10}",
        "clients", "pts/req", "req/s", "points/s", "mean lat µs", "fill"
    );

    for (clients, pts) in [(1usize, 1usize), (4, 16), (16, 16), (8, 64), (32, 8)] {
        let backend_mlp = mlp.clone();
        let service = Service::start(
            move || Ok(Box::new(NativeBackend::new(backend_mlp, 3, 256)) as _),
            BatcherConfig::default(),
        );
        let handle = service.handle();
        let reqs_per_client = 200usize;
        let start = Instant::now();
        let mut threads = Vec::new();
        for c in 0..clients {
            let handle = handle.clone();
            threads.push(std::thread::spawn(move || {
                let points: Vec<f64> = (0..pts).map(|i| (c * pts + i) as f64 * 1e-3).collect();
                for _ in 0..reqs_per_client {
                    let out = handle.eval(&points).unwrap();
                    std::hint::black_box(&out);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let secs = start.elapsed().as_secs_f64();
        let m = handle.metrics();
        println!(
            "{clients:>8} {pts:>10} {:>14.0} {:>14.0} {:>12.0} {:>10.2}",
            m.requests as f64 / secs,
            m.points as f64 / secs,
            m.mean_latency_us,
            m.mean_batch_fill
        );
        service.shutdown();
    }

    // Sharded worker pool under mixed-activation traffic: 16 clients,
    // each pinned to one of the four registered towers, against 1/2/4
    // workers. More workers = more activation shards running concurrently.
    println!("\n# worker pool, 16 mixed-activation clients, 16 pts/req");
    println!(
        "{:>8} {:>14} {:>14} {:>12} {:>14}",
        "workers", "req/s", "points/s", "mean lat µs", "busy workers"
    );
    for workers in [1usize, 2, 4] {
        let backend_mlp = mlp.clone();
        let service = Service::start_pool(
            move |_w| Ok(Box::new(NativeBackend::new(backend_mlp.clone(), 3, 256)) as _),
            workers,
            BatcherConfig::default(),
        );
        let handle = service.handle();
        let reqs_per_client = 200usize;
        let start = Instant::now();
        let mut threads = Vec::new();
        for c in 0..16usize {
            let handle = handle.clone();
            let kind = ActivationKind::ALL[c % ActivationKind::ALL.len()];
            threads.push(std::thread::spawn(move || {
                let points: Vec<f64> = (0..16).map(|i| (c * 16 + i) as f64 * 1e-3).collect();
                for _ in 0..reqs_per_client {
                    let out = handle.eval_with(&points, Some(kind)).unwrap();
                    std::hint::black_box(&out);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let secs = start.elapsed().as_secs_f64();
        let m = handle.metrics();
        let busy = m.workers.iter().filter(|w| w.requests > 0).count();
        println!(
            "{workers:>8} {:>14.0} {:>14.0} {:>12.0} {busy:>14}",
            m.requests as f64 / secs,
            m.points as f64 / secs,
            m.mean_latency_us,
        );
        service.shutdown();
    }
}
