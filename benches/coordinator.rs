//! Bench: coordinator throughput/latency — request batching over the
//! native backend, single worker (the serving-path hot loop).
//!
//!     cargo bench --bench coordinator

use ntangent::coordinator::{BatcherConfig, NativeBackend, Service};
use ntangent::nn::Mlp;
use ntangent::util::prng::Prng;
use std::time::Instant;

fn main() {
    let mut rng = Prng::seeded(31);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    println!("# coordinator: n=3 channels, native backend, batch cap 256");
    println!(
        "{:>8} {:>10} {:>14} {:>14} {:>12} {:>10}",
        "clients", "pts/req", "req/s", "points/s", "mean lat µs", "fill"
    );

    for (clients, pts) in [(1usize, 1usize), (4, 16), (16, 16), (8, 64), (32, 8)] {
        let backend_mlp = mlp.clone();
        let service = Service::start(
            move || Ok(Box::new(NativeBackend::new(backend_mlp, 3, 256)) as _),
            BatcherConfig::default(),
        );
        let handle = service.handle();
        let reqs_per_client = 200usize;
        let start = Instant::now();
        let mut threads = Vec::new();
        for c in 0..clients {
            let handle = handle.clone();
            threads.push(std::thread::spawn(move || {
                let points: Vec<f64> = (0..pts).map(|i| (c * pts + i) as f64 * 1e-3).collect();
                for _ in 0..reqs_per_client {
                    let out = handle.eval(&points).unwrap();
                    std::hint::black_box(&out);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let secs = start.elapsed().as_secs_f64();
        let m = handle.metrics();
        println!(
            "{clients:>8} {pts:>10} {:>14.0} {:>14.0} {:>12.0} {:>10.2}",
            m.requests as f64 / secs,
            m.points as f64 / secs,
            m.mean_latency_us,
            m.mean_batch_fill
        );
        service.shutdown();
    }
}
