//! The paper's showcase (§IV-C1): compute smooth *unstable* self-similar
//! Burgers profiles. Profiles k = 2, 3, 4 need 5, 7, 9 derivatives per
//! loss evaluation — the regime where repeated autodiff is intractable
//! and n-TangentProp makes training feasible.
//!
//!     cargo run --release --example burgers_profiles [k_max] [epochs]

use ntangent::pinn::{train_burgers, BurgersLossSpec, DerivEngine, TrainConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let k_max: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(250);

    println!("smooth self-similar Burgers profiles: λ_k = 1/(2k)\n");
    for k in 1..=k_max {
        let spec = BurgersLossSpec::for_profile(k);
        let (lo, hi) = spec.profile.lambda_range();
        println!(
            "profile k={k}: λ ∈ [{lo:.4}, {hi:.4}], target λ* = {:.4}, needs {} derivatives",
            spec.profile.lambda_smooth(),
            spec.profile.n_derivs()
        );
        let cfg = TrainConfig {
            width: 24,
            depth: 3,
            adam_epochs: epochs,
            lbfgs_epochs: epochs,
            adam_lr: 2e-3,
            seed: k as u64,
            log_every: 50,
            ..TrainConfig::default()
        };
        let result = train_burgers(spec, &cfg, DerivEngine::Ntp);
        println!(
            "  {:.1}s: λ = {:.6} (err {:.2e}), loss {:.3e}, L2(u) {:.3e}, fwd/bwd evals {}/{}\n",
            result.seconds,
            result.lambda,
            result.lambda_error(),
            result.final_loss,
            result.solution_l2_error(101),
            result.n_forward,
            result.n_backward,
        );
    }
    println!("(the paper computes k=3 in <1h on an A6000 with n-TangentProp;");
    println!(" the projected autodiff time was >25h — run `ntangent bench fig7` for the full reproduction)");
}
