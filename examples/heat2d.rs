//! Heat-equation PINN end to end: train `u(t, x)` against
//! `u_t − κ·u_xx = 0` with Dirichlet data from the exact solution, then
//! audit the residual and the error through the directional-jet engine.
//!
//!     cargo run --release --example heat2d

use ntangent::ntp::ParallelPolicy;
use ntangent::pde::PdeProblem;
use ntangent::pinn::{residual_values, train_pde, DerivEngine, MultiPinnSpec, TrainConfig};
use ntangent::util::prng::Prng;

fn main() {
    let problem = PdeProblem::Heat2d;
    let op = problem.operator();
    println!(
        "problem {}: L = {} (order {}), exact u* = exp(-κπ²t)·sin(πx)",
        problem.name(),
        op.describe(),
        op.max_order()
    );

    // Small, CPU-friendly setup; the mixed partials inside the residual
    // come from batched directional n-TangentProp passes.
    let mut spec = MultiPinnSpec::for_problem(problem);
    spec.n_interior = 192;
    spec.n_boundary = 48;
    let cfg = TrainConfig {
        width: 16,
        depth: 2,
        adam_epochs: 400,
        lbfgs_epochs: 200,
        seed: 7,
        policy: ParallelPolicy::Auto,
        ..TrainConfig::default()
    };

    println!(
        "training {}x{} tanh net on {} interior + {} boundary points...",
        cfg.depth, cfg.width, spec.n_interior, spec.n_boundary
    );
    let result = train_pde(spec, &cfg, DerivEngine::Ntp);
    println!(
        "done in {:.1}s: loss {:.3e}, residual RMS {:.3e}, L2(u - u*) {:.3e}",
        result.seconds,
        result.final_loss,
        result.residual_rms(512, 1),
        result.solution_l2_error(512, 2),
    );

    // Audit the residual on a fresh cloud: one direction-stacked fused
    // batch evaluates u_t - κ·u_xx at every point.
    let mut rng = Prng::seeded(3);
    let xs = problem.sample_interior(6, &mut rng);
    let r = residual_values(problem, &result.mlp, &xs, ParallelPolicy::Serial);
    let u_all = result.mlp.forward(&xs);
    println!("\n{:>10} {:>10} {:>14} {:>14} {:>14}", "t", "x", "u", "u*", "residual");
    for (i, p) in xs.data().chunks_exact(2).enumerate() {
        println!(
            "{:>10.4} {:>10.4} {:>14.6} {:>14.6} {:>14.2e}",
            p[0],
            p[1],
            u_all.data()[i],
            problem.u_exact(p),
            r.data()[i]
        );
    }
}
