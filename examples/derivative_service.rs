//! The serving path: run the coordinator over the AOT-compiled PJRT
//! artifact (python never in the loop), hit it over TCP with concurrent
//! clients, and compare against the native engine.
//!
//! Requires `make artifacts`. Falls back to the native backend with a
//! notice when the bundle is missing.
//!
//!     cargo run --release --example derivative_service

use ntangent::coordinator::service::TcpClient;
use ntangent::coordinator::{BatcherConfig, NativeBackend, PjrtBackend, Service};
use ntangent::nn::{params, Mlp};
use ntangent::ntp::NtpEngine;
use ntangent::runtime::{ArtifactManifest, Runtime};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use std::net::TcpListener;
use std::path::Path;

fn main() {
    let mut rng = Prng::seeded(2024);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    let theta = params::flatten(&mlp);
    let n = 3;

    let artifacts = Path::new("artifacts");
    let have_artifacts = ArtifactManifest::load(artifacts).is_ok();
    let backend_name = if have_artifacts { "pjrt" } else { "native" };
    println!("starting derivative-evaluation service ({backend_name} backend, n = {n})");

    let mlp_for_backend = mlp.clone();
    let theta_for_backend = theta.clone();
    let service = Service::start(
        move || {
            if have_artifacts {
                let manifest = ArtifactManifest::load(Path::new("artifacts"))?;
                let spec = manifest.get("ntp_fwd_d3")?.clone();
                let rt = Runtime::cpu()?;
                let exe = rt.load_hlo_text(&manifest.path_of(&spec))?;
                println!("  compiled {} on {}", spec.file, rt.platform());
                Ok(Box::new(PjrtBackend::new(
                    exe,
                    theta_for_backend,
                    spec.batch.unwrap_or(256),
                    spec.n_derivs.unwrap_or(3),
                )) as _)
            } else {
                println!("  (artifacts missing; using the native Rust engine)");
                Ok(Box::new(NativeBackend::new(mlp_for_backend, 3, 256)) as _)
            }
        },
        BatcherConfig::default(),
    );

    // TCP front on an ephemeral port.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    println!("  listening on {addr}");
    let handle = service.handle();
    std::thread::spawn(move || ntangent::coordinator::service::serve_tcp(listener, handle));

    // Concurrent TCP clients.
    let mut threads = Vec::new();
    for c in 0..8 {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = TcpClient::connect(&addr).unwrap();
            let pts: Vec<f64> = (0..32).map(|i| -1.0 + (c * 32 + i) as f64 / 128.0).collect();
            let channels = client.eval(&pts).unwrap();
            (pts, channels)
        }));
    }

    // Verify every response against the native engine.
    let engine = NtpEngine::new(n);
    let mut checked = 0usize;
    for th in threads {
        let (pts, channels) = th.join().unwrap();
        let x = Tensor::from_vec(pts.clone(), &[pts.len(), 1]);
        let native = engine.forward(&mlp, &x);
        for order in 0..=n {
            for (a, b) in channels[order].iter().zip(native[order].data()) {
                assert!(
                    (a - b).abs() < 1e-7 * b.abs().max(1.0),
                    "service/native mismatch at order {order}"
                );
                checked += 1;
            }
        }
    }

    let mut client = TcpClient::connect(&addr).unwrap();
    println!("  verified {checked} values against the native engine");
    println!("  server stats: {}", client.stats().unwrap());
    service.shutdown();
    println!("ok");
}
