//! Sobolev training (paper eq. (2)): supervising derivatives, not just
//! values, improves convergence — and n-TangentProp makes high Sobolev
//! orders affordable (the paper hopes future work trains with m >= 4).
//!
//! We fit u(x) = sin(3x)·exp(-x²/2) with plain L2 loss vs Sobolev losses
//! of increasing order m, all via n-TangentProp channels, and report the
//! error in u and u' on a held-out grid.
//!
//!     cargo run --release --example sobolev_training [epochs]

use ntangent::autodiff::Graph;
use ntangent::nn::{params, Mlp};
use ntangent::ntp::NtpEngine;
use ntangent::opt::{Adam, Objective};
use ntangent::pinn::grid_points;
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;

fn target(x: f64, order: usize) -> f64 {
    // Derivatives of sin(3x)·exp(-x²/2) via a small finite tower (exact
    // enough for supervision targets; computed by nested closed forms).
    match order {
        0 => (3.0 * x).sin() * (-x * x / 2.0).exp(),
        1 => {
            let e = (-x * x / 2.0).exp();
            e * (3.0 * (3.0 * x).cos() - x * (3.0 * x).sin())
        }
        2 => {
            let e = (-x * x / 2.0).exp();
            let s = (3.0 * x).sin();
            let c = (3.0 * x).cos();
            e * ((x * x - 10.0) * s - 6.0 * x * c)
        }
        _ => panic!("order > 2 targets not needed here"),
    }
}

/// Sobolev-m regression objective over ntp channels.
struct SobolevFit {
    graph: Graph,
    loss: usize,
    grads: Vec<usize>,
    template: Mlp,
}

impl SobolevFit {
    fn build(mlp: &Mlp, xs: &Tensor, m: usize) -> SobolevFit {
        let engine = NtpEngine::new(m);
        let mut g = Graph::new();
        let pn = mlp.input_param_nodes(&mut g);
        let xn = g.constant(xs.clone());
        let channels = engine.forward_graph(&mut g, mlp, xn, &pn, m);
        let mut loss = None;
        for (order, &c) in channels.iter().enumerate() {
            let targets: Vec<f64> = xs.data().iter().map(|&x| target(x, order)).collect();
            let tn = g.constant(Tensor::from_vec(targets, &[xs.shape()[0], 1]));
            let d = g.sub(c, tn);
            let ms = g.mean_square(d);
            // Down-weight higher orders (they have larger magnitudes).
            let w = g.scale(ms, 1.0 / (1 + order * order) as f64);
            loss = Some(match loss {
                None => w,
                Some(acc) => g.add(acc, w),
            });
        }
        let loss = loss.unwrap();
        let grads = g.backward(loss, &pn);
        SobolevFit {
            graph: g,
            loss,
            grads,
            template: mlp.clone(),
        }
    }
}

impl Objective for SobolevFit {
    fn value_grad(&mut self, theta: &Tensor) -> (f64, Tensor) {
        let inputs = params::split_like(&self.template, theta);
        let mut targets = self.grads.clone();
        targets.push(self.loss);
        let vals = self.graph.eval(&inputs, &targets);
        let loss = vals.get(self.loss).item();
        let grads: Vec<Tensor> = self.grads.iter().map(|&id| vals.get(id).clone()).collect();
        (loss, params::flatten_tensors(&grads))
    }

    fn dim(&self) -> usize {
        self.template.n_params()
    }
}

fn main() {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1500);
    let xs = grid_points(-2.0, 2.0, 64);
    let holdout = grid_points(-1.9, 1.9, 97);

    println!("fitting sin(3x)·exp(-x²/2), {epochs} Adam epochs, 2x24 tanh net");
    println!(
        "{:>10} {:>14} {:>14} {:>12}",
        "Sobolev m", "RMS(u)", "RMS(u')", "final loss"
    );
    for m in 0..=2usize {
        let mut rng = Prng::seeded(100);
        let mlp = Mlp::uniform(1, 24, 2, 1, &mut rng);
        let mut obj = SobolevFit::build(&mlp, &xs, m);
        let mut theta = params::flatten(&mlp);
        let mut adam = Adam::new(theta.numel(), 3e-3);
        let mut final_loss = 0.0;
        for _ in 0..epochs {
            final_loss = adam.step(&mut obj, &mut theta);
        }
        // Held-out error in u and u'.
        let mut fitted = mlp.clone();
        params::unflatten_into(&mut fitted, &theta);
        let engine = NtpEngine::new(1);
        let out = engine.forward(&fitted, &holdout);
        let mut rms = [0.0f64; 2];
        for (i, &x) in holdout.data().iter().enumerate() {
            for order in 0..2 {
                let d = out[order].data()[i] - target(x, order);
                rms[order] += d * d;
            }
        }
        let npts = holdout.shape()[0] as f64;
        println!(
            "{m:>10} {:>14.4e} {:>14.4e} {final_loss:>12.3e}",
            (rms[0] / npts).sqrt(),
            (rms[1] / npts).sqrt()
        );
    }
    println!("\nhigher m supervises derivatives directly: u' error drops sharply");
    println!("while n-TangentProp keeps the extra channels cheap (quasilinear in m).");
}
