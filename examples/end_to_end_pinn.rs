//! End-to-end driver (the EXPERIMENTS.md §E2E run): train the first
//! self-similar Burgers profile with BOTH derivative engines on a real
//! workload, log the loss/λ curves, verify against the analytic profile,
//! and then serve the trained model through the batching coordinator —
//! proving all layers compose: substrate → engine → PINN trainer →
//! checkpoint → coordinator.
//!
//!     cargo run --release --example end_to_end_pinn [adam_epochs] [lbfgs_epochs]

use ntangent::coordinator::{BatcherConfig, NativeBackend, Service};
use ntangent::nn::Checkpoint;
use ntangent::pinn::{train_burgers, BurgersLossSpec, DerivEngine, TrainConfig};
use ntangent::util::csv::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let adam: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(400);
    let lbfgs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400);

    let spec = BurgersLossSpec::for_profile(1);
    let cfg = TrainConfig {
        width: 24,
        depth: 3,
        adam_epochs: adam,
        lbfgs_epochs: lbfgs,
        adam_lr: 2e-3,
        seed: 0,
        log_every: 25,
        ..TrainConfig::default()
    };

    println!("== phase 1: train profile k=1 (λ* = 0.5, 3 derivatives) ==");
    println!("   n-TangentProp engine ...");
    let ntp = train_burgers(spec.clone(), &cfg, DerivEngine::Ntp);
    println!(
        "   done {:.1}s  λ={:.6} (err {:.1e})  loss={:.3e}  L2(u)={:.3e}",
        ntp.seconds,
        ntp.lambda,
        ntp.lambda_error(),
        ntp.final_loss,
        ntp.solution_l2_error(201)
    );
    println!("   repeated-autodiff engine (the baseline) ...");
    let ad = train_burgers(spec, &cfg, DerivEngine::Autodiff);
    println!(
        "   done {:.1}s  λ={:.6} (err {:.1e})  loss={:.3e}",
        ad.seconds,
        ad.lambda,
        ad.lambda_error(),
        ad.final_loss
    );
    println!(
        "   end-to-end speedup (autodiff/ntp): {:.2}x  (paper: 2.5x on GPU)",
        ad.seconds / ntp.seconds
    );

    // Log the loss curve.
    let mut t = Table::new(&["epoch", "phase", "loss", "lambda", "elapsed_s"]);
    for log in &ntp.logs {
        t.push(vec![
            log.epoch.to_string(),
            log.phase.to_string(),
            format!("{:.6e}", log.loss),
            format!("{:.8}", log.lambda),
            format!("{:.3}", log.elapsed),
        ]);
    }
    std::fs::create_dir_all("results").unwrap();
    t.save(std::path::Path::new("results/e2e_loss_curve.csv")).unwrap();
    println!("   loss curve -> results/e2e_loss_curve.csv");

    println!("\n== phase 2: verify against the analytic profile ==");
    let profile = ntp.profile;
    for x in [-1.5, -0.75, 0.0, 0.75, 1.5] {
        let u = ntp
            .mlp
            .forward(&ntangent::tensor::Tensor::from_vec(vec![x], &[1, 1]))
            .data()[0];
        let truth = profile.u_true(x);
        println!("   x={x:>6.2}  learned={u:>10.6}  true={truth:>10.6}  |err|={:.2e}", (u - truth).abs());
    }

    println!("\n== phase 3: checkpoint + serve through the coordinator ==");
    let mut ck = Checkpoint::from_mlp(&ntp.mlp);
    ck.lambda = Some(ntp.lambda);
    ck.profile_k = Some(1);
    ck.save(std::path::Path::new("results/e2e_checkpoint.json")).unwrap();
    let mlp = ck.to_mlp().unwrap();
    let service = Service::start(
        move || Ok(Box::new(NativeBackend::new(mlp, 3, 256)) as _),
        BatcherConfig::default(),
    );
    let handle = service.handle();
    // Fire a burst of concurrent clients.
    let mut threads = Vec::new();
    for t in 0..16 {
        let handle = handle.clone();
        threads.push(std::thread::spawn(move || {
            let pts: Vec<f64> = (0..64).map(|i| -1.5 + (t as f64 * 64.0 + i as f64) * 0.002).collect();
            handle.eval(&pts).unwrap().len()
        }));
    }
    for th in threads {
        assert_eq!(th.join().unwrap(), 4); // u..u''' channels
    }
    let m = handle.metrics();
    println!(
        "   served {} requests / {} points in {} batches (fill {:.1} req/batch, mean latency {:.0}µs)",
        m.requests, m.points, m.batches, m.mean_batch_fill, m.mean_latency_us
    );
    service.shutdown();
    println!("\nall phases OK");
}
