//! Quickstart: compute high-order derivatives of a network two ways and
//! verify they agree exactly; then peek at the cost asymmetry.
//!
//!     cargo run --release --example quickstart

use ntangent::autodiff::{higher, Graph};
use ntangent::nn::Mlp;
use ntangent::ntp::{ActivationKind, NtpEngine};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use std::time::Instant;

fn main() {
    // The paper's standard PINN network: 3 hidden layers of 24, tanh.
    let mut rng = Prng::seeded(42);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    let x = Tensor::linspace(-1.0, 1.0, 8).reshape(&[8, 1]);
    let n = 5;

    // --- n-TangentProp: all derivatives in one forward pass -----------
    let t0 = Instant::now();
    let engine = NtpEngine::new(n);
    let channels = engine.forward(&mlp, &x);
    let t_ntp = t0.elapsed();

    // --- Baseline: repeated reverse-mode autodiff ----------------------
    let t1 = Instant::now();
    let mut g = Graph::new();
    let xn = g.input(x.shape());
    let pn = mlp.const_param_nodes(&mut g);
    let u = mlp.forward_graph(&mut g, xn, &pn);
    let stack = higher::derivative_stack(&mut g, u, xn, n);
    let vals = g.eval(&[x.clone()], &stack);
    let t_ad = t1.elapsed();

    println!("derivatives of a 3x24 tanh MLP at 8 points, n = {n}:");
    println!(
        "{:>10} {:>16} {:>16} {:>12}",
        "order", "ntp", "autodiff", "max |diff|"
    );
    for order in 0..=n {
        let a = channels[order].data();
        let b = vals.get(stack[order]).data();
        let worst = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        println!("{order:>10} {:>16.8} {:>16.8} {worst:>12.2e}", a[4], b[4]);
        assert!(worst < 1e-8, "engines disagree!");
    }
    println!("\nn-TangentProp: {t_ntp:?}   repeated autodiff: {t_ad:?}");
    println!("autodiff graph grew to {} nodes (exponential in n);", g.len());
    println!(
        "n-TangentProp used {} Faà di Bruno terms (quasilinear).",
        engine.tables().total_terms(n)
    );

    // --- Activation selection: the same engine serves every registered
    // tower. A sine-activated (SIREN-style) network, checked against its
    // own repeated-autodiff baseline:
    let siren = Mlp::with_activation(&[1, 24, 24, 1], ActivationKind::Sine, &mut rng);
    let sine_channels = engine.forward(&siren, &x);
    let mut g2 = Graph::new();
    let xn2 = g2.input(x.shape());
    let pn2 = siren.const_param_nodes(&mut g2);
    let u2 = siren.forward_graph(&mut g2, xn2, &pn2);
    let stack2 = higher::derivative_stack(&mut g2, u2, xn2, n);
    let vals2 = g2.eval(&[x], &stack2);
    let worst = (0..=n)
        .flat_map(|order| {
            sine_channels[order]
                .data()
                .iter()
                .zip(vals2.get(stack2[order]).data())
                .map(|(a, b)| (a - b).abs())
                .collect::<Vec<_>>()
        })
        .fold(0.0f64, f64::max);
    println!("\nsine-activated network (SIREN-style): engines agree to {worst:.2e}");
    assert!(worst < 1e-8, "sine engines disagree!");
}
